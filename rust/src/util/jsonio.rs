//! JSON (de)serialization impls for every persisted type, centralized so
//! the domain modules stay serialization-free.

use std::sync::Arc;

use super::json::Json;
use crate::arrivals::{ArrivalModel, ArrivalProfile};
use crate::coordinator::config::{
    ArrivalSpec, ExperimentConfig, RetentionConfig, RuntimeViewConfig,
};
use crate::coordinator::params::{ModelLaws, SimParams};
use crate::coordinator::strategy::StrategySpec;
use crate::empirical::{AnalyticsDb, AssetRecord, EvalRecord, JobRecord, PreprocRecord};
use crate::error::{Error, Result};
use crate::model::{
    ClusterFailureConfig, FailureModel, FaultModel, Framework, HwClass, HwClasses, InfraConfig,
    StoreConfig, TaskFaultConfig,
};
use crate::stats::dist::{Dist, ExpWeibull, Exponential, LogNormal, Normal, Pareto, Weibull};
use crate::stats::gmm::{Gmm1, Gmm3};
use crate::stats::ExpCurve;
use crate::synth::SynthConfig;

/// Symmetric JSON conversion.
pub trait JsonIo: Sized {
    fn to_json(&self) -> Json;
    fn from_json(j: &Json) -> Result<Self>;

    fn save_json(&self, path: &std::path::Path) -> Result<()> {
        self.to_json().save(path)
    }

    fn load_json(path: &std::path::Path) -> Result<Self> {
        Self::from_json(&Json::load(path)?)
    }
}

// ---------------------------------------------------------------------
// enums with string forms
// ---------------------------------------------------------------------

impl Framework {
    pub fn parse_name(s: &str) -> Result<Framework> {
        Framework::ALL
            .iter()
            .find(|f| f.name() == s)
            .copied()
            .ok_or_else(|| Error::Other(format!("unknown framework '{s}'")))
    }
}

impl JsonIo for StrategySpec {
    /// Canonical form: `{"name": "...", "params": {"key": value, ...}}`.
    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("name".to_string(), Json::Str(self.name.clone())),
            (
                "params".to_string(),
                Json::Obj(
                    self.params
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Num(*v)))
                        .collect(),
                ),
            ),
        ])
    }

    /// Accepts the canonical form, a bare string (`"fifo"` — the legacy
    /// `discipline` encoding), and the legacy trigger encoding
    /// `{"policy": "...", <params inline>}`.
    fn from_json(j: &Json) -> Result<Self> {
        if let Json::Str(s) = j {
            return Ok(StrategySpec::new(s.as_str()));
        }
        let name = match j.get("name") {
            Some(n) => n.as_str()?,
            None => j.s("policy")?,
        };
        let mut spec = StrategySpec::new(name);
        match j.get("params") {
            Some(Json::Obj(fields)) => {
                for (k, v) in fields {
                    spec.params.push((k.clone(), v.as_f64()?));
                }
            }
            Some(Json::Null) | None => {
                // legacy inline form: every field besides the tag (and an
                // explicit null "params") is a numeric parameter
                if let Json::Obj(fields) = j {
                    for (k, v) in fields {
                        if k != "policy" && k != "name" && k != "params" {
                            spec.params.push((k.clone(), v.as_f64()?));
                        }
                    }
                }
            }
            Some(other) => {
                return Err(Error::Other(format!(
                    "strategy params must be an object, got {other:?}"
                )))
            }
        }
        Ok(spec)
    }
}

// ---------------------------------------------------------------------
// distributions
// ---------------------------------------------------------------------

impl JsonIo for Dist {
    fn to_json(&self) -> Json {
        match self {
            Dist::Normal(d) => Json::obj(vec![
                ("family", Json::Str("normal".into())),
                ("mu", Json::Num(d.mu)),
                ("sigma", Json::Num(d.sigma)),
            ]),
            Dist::LogNormal(d) => Json::obj(vec![
                ("family", Json::Str("lognormal".into())),
                ("mu", Json::Num(d.mu)),
                ("sigma", Json::Num(d.sigma)),
            ]),
            Dist::Exponential(d) => Json::obj(vec![
                ("family", Json::Str("exponential".into())),
                ("lambda", Json::Num(d.lambda)),
            ]),
            Dist::Weibull(d) => Json::obj(vec![
                ("family", Json::Str("weibull".into())),
                ("k", Json::Num(d.k)),
                ("lambda", Json::Num(d.lambda)),
            ]),
            Dist::ExpWeibull(d) => Json::obj(vec![
                ("family", Json::Str("expweibull".into())),
                ("alpha", Json::Num(d.alpha)),
                ("k", Json::Num(d.k)),
                ("lambda", Json::Num(d.lambda)),
            ]),
            Dist::Pareto(d) => Json::obj(vec![
                ("family", Json::Str("pareto".into())),
                ("xm", Json::Num(d.xm)),
                ("alpha", Json::Num(d.alpha)),
            ]),
        }
    }
    fn from_json(j: &Json) -> Result<Self> {
        Ok(match j.s("family")? {
            "normal" => Dist::Normal(Normal::new(j.f("mu")?, j.f("sigma")?)),
            "lognormal" => Dist::LogNormal(LogNormal::new(j.f("mu")?, j.f("sigma")?)),
            "exponential" => Dist::Exponential(Exponential::new(j.f("lambda")?)),
            "weibull" => Dist::Weibull(Weibull::new(j.f("k")?, j.f("lambda")?)),
            "expweibull" => {
                Dist::ExpWeibull(ExpWeibull::new(j.f("alpha")?, j.f("k")?, j.f("lambda")?))
            }
            "pareto" => Dist::Pareto(Pareto::new(j.f("xm")?, j.f("alpha")?)),
            s => return Err(Error::Other(format!("unknown family '{s}'"))),
        })
    }
}

impl JsonIo for LogNormal {
    fn to_json(&self) -> Json {
        Json::obj(vec![("mu", Json::Num(self.mu)), ("sigma", Json::Num(self.sigma))])
    }
    fn from_json(j: &Json) -> Result<Self> {
        Ok(LogNormal::new(j.f("mu")?, j.f("sigma")?))
    }
}

impl JsonIo for ExpCurve {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("a", Json::Num(self.a)),
            ("b", Json::Num(self.b)),
            ("c", Json::Num(self.c)),
        ])
    }
    fn from_json(j: &Json) -> Result<Self> {
        Ok(ExpCurve {
            a: j.f("a")?,
            b: j.f("b")?,
            c: j.f("c")?,
        })
    }
}

// ---------------------------------------------------------------------
// mixtures (matrices stored flat, row-major)
// ---------------------------------------------------------------------

impl JsonIo for Gmm3 {
    fn to_json(&self) -> Json {
        let flat3 = |m: &Vec<[f64; 3]>| Json::arr_f64(m.iter().flatten().cloned());
        let flat33 = |m: &Vec<[[f64; 3]; 3]>| {
            Json::arr_f64(m.iter().flat_map(|a| a.iter().flatten().cloned()))
        };
        Json::obj(vec![
            ("logw", Json::arr_f64(self.logw.iter().cloned())),
            ("mu", flat3(&self.mu)),
            ("cchol", flat33(&self.cchol)),
            ("pchol", flat33(&self.pchol)),
        ])
    }
    fn from_json(j: &Json) -> Result<Self> {
        let logw = j.req("logw")?.as_f64_vec()?;
        let k = logw.len();
        let mu_flat = j.req("mu")?.as_f64_vec()?;
        let cchol_flat = j.req("cchol")?.as_f64_vec()?;
        let pchol_flat = j.req("pchol")?.as_f64_vec()?;
        if mu_flat.len() != k * 3 || cchol_flat.len() != k * 9 || pchol_flat.len() != k * 9 {
            return Err(Error::Other("gmm3: shape mismatch".into()));
        }
        let mu = mu_flat.chunks(3).map(|c| [c[0], c[1], c[2]]).collect();
        let unflat = |flat: &[f64]| {
            flat.chunks(9)
                .map(|c| {
                    [
                        [c[0], c[1], c[2]],
                        [c[3], c[4], c[5]],
                        [c[6], c[7], c[8]],
                    ]
                })
                .collect()
        };
        Ok(Gmm3 {
            logw,
            mu,
            cchol: unflat(&cchol_flat),
            pchol: unflat(&pchol_flat),
        })
    }
}

impl JsonIo for Gmm1 {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("logw", Json::arr_f64(self.logw.iter().cloned())),
            ("mu", Json::arr_f64(self.mu.iter().cloned())),
            ("logsd", Json::arr_f64(self.logsd.iter().cloned())),
        ])
    }
    fn from_json(j: &Json) -> Result<Self> {
        let g = Gmm1 {
            logw: j.req("logw")?.as_f64_vec()?,
            mu: j.req("mu")?.as_f64_vec()?,
            logsd: j.req("logsd")?.as_f64_vec()?,
        };
        if g.mu.len() != g.logw.len() || g.logsd.len() != g.logw.len() {
            return Err(Error::Other("gmm1: shape mismatch".into()));
        }
        Ok(g)
    }
}

// ---------------------------------------------------------------------
// arrivals
// ---------------------------------------------------------------------

impl JsonIo for ArrivalModel {
    fn to_json(&self) -> Json {
        match self {
            ArrivalModel::Random(d) => Json::obj(vec![
                ("mode", Json::Str("random".into())),
                ("dist", d.to_json()),
            ]),
            ArrivalModel::Profile(p) => Json::obj(vec![
                ("mode", Json::Str("profile".into())),
                ("clusters", Json::Arr(p.clusters.iter().map(|d| d.to_json()).collect())),
                ("sse", Json::arr_f64(p.sse.iter().cloned())),
            ]),
            ArrivalModel::Poisson { mean_interarrival } => Json::obj(vec![
                ("mode", Json::Str("poisson".into())),
                ("mean_interarrival", Json::Num(*mean_interarrival)),
            ]),
            ArrivalModel::Replay(trace) => Json::obj(vec![
                ("mode", Json::Str("replay".into())),
                ("gaps", Json::arr_f64(trace.gaps.iter().cloned())),
            ]),
        }
    }
    fn from_json(j: &Json) -> Result<Self> {
        Ok(match j.s("mode")? {
            "random" => ArrivalModel::Random(Dist::from_json(j.req("dist")?)?),
            "profile" => {
                let clusters = j
                    .req("clusters")?
                    .as_arr()?
                    .iter()
                    .map(Dist::from_json)
                    .collect::<Result<Vec<_>>>()?;
                let sse = j.req("sse")?.as_f64_vec()?;
                if clusters.len() != 168 {
                    return Err(Error::Other(format!(
                        "profile: {} clusters, expected 168",
                        clusters.len()
                    )));
                }
                ArrivalModel::Profile(Arc::new(ArrivalProfile { clusters, sse }))
            }
            "poisson" => ArrivalModel::Poisson {
                mean_interarrival: j.f("mean_interarrival")?,
            },
            "replay" => ArrivalModel::Replay(crate::arrivals::ReplayTrace::new(
                j.req("gaps")?.as_f64_vec()?,
            )),
            s => return Err(Error::Other(format!("unknown arrival mode '{s}'"))),
        })
    }
}

// ---------------------------------------------------------------------
// sim params
// ---------------------------------------------------------------------

impl JsonIo for ModelLaws {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("perf_mean", Json::Num(self.perf_mean)),
            ("perf_sd", Json::Num(self.perf_sd)),
            ("size_ln_mean", Json::Num(self.size_ln_mean)),
            ("size_ln_sd", Json::Num(self.size_ln_sd)),
            ("inference_ln_mean", Json::Num(self.inference_ln_mean)),
            ("inference_ln_sd", Json::Num(self.inference_ln_sd)),
            ("clever_max", Json::Num(self.clever_max)),
        ])
    }
    fn from_json(j: &Json) -> Result<Self> {
        Ok(ModelLaws {
            perf_mean: j.f("perf_mean")?,
            perf_sd: j.f("perf_sd")?,
            size_ln_mean: j.f("size_ln_mean")?,
            size_ln_sd: j.f("size_ln_sd")?,
            inference_ln_mean: j.f("inference_ln_mean")?,
            inference_ln_sd: j.f("inference_ln_sd")?,
            clever_max: j.f("clever_max")?,
        })
    }
}

impl JsonIo for SimParams {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("asset_gmm", self.asset_gmm.to_json()),
            (
                "train_log_gmm",
                Json::Arr(self.train_log_gmm.iter().map(|g| g.to_json()).collect()),
            ),
            ("eval_log_gmm", self.eval_log_gmm.to_json()),
            ("preproc_curve", self.preproc_curve.to_json()),
            ("preproc_noise", self.preproc_noise.to_json()),
            ("arrival_random", self.arrival_random.to_json()),
            ("arrival_profile", self.arrival_profile.to_json()),
            ("arrival_replay", self.arrival_replay.to_json()),
            ("mean_interarrival", Json::Num(self.mean_interarrival)),
            ("model_laws", self.model_laws.to_json()),
        ])
    }
    fn from_json(j: &Json) -> Result<Self> {
        Ok(SimParams {
            asset_gmm: Arc::new(Gmm3::from_json(j.req("asset_gmm")?)?),
            train_log_gmm: j
                .req("train_log_gmm")?
                .as_arr()?
                .iter()
                .map(|g| Gmm1::from_json(g).map(Arc::new))
                .collect::<Result<Vec<_>>>()?,
            eval_log_gmm: Arc::new(Gmm1::from_json(j.req("eval_log_gmm")?)?),
            preproc_curve: ExpCurve::from_json(j.req("preproc_curve")?)?,
            preproc_noise: LogNormal::from_json(j.req("preproc_noise")?)?,
            arrival_random: ArrivalModel::from_json(j.req("arrival_random")?)?,
            arrival_profile: ArrivalModel::from_json(j.req("arrival_profile")?)?,
            arrival_replay: ArrivalModel::from_json(j.req("arrival_replay")?)?,
            mean_interarrival: j.f("mean_interarrival")?,
            model_laws: ModelLaws::from_json(j.req("model_laws")?)?,
        })
    }
}

// ---------------------------------------------------------------------
// analytics DB (columnar for compactness/speed)
// ---------------------------------------------------------------------

impl JsonIo for AnalyticsDb {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("weeks", Json::Num(self.weeks as f64)),
            ("job_t", Json::arr_f64(self.jobs.iter().map(|r| r.t))),
            (
                "job_fw",
                Json::arr_f64(self.jobs.iter().map(|r| r.framework.index() as f64)),
            ),
            ("job_dur", Json::arr_f64(self.jobs.iter().map(|r| r.duration))),
            ("asset_rows", Json::arr_f64(self.assets.iter().map(|r| r.rows))),
            ("asset_cols", Json::arr_f64(self.assets.iter().map(|r| r.cols))),
            ("asset_bytes", Json::arr_f64(self.assets.iter().map(|r| r.bytes))),
            ("pre_rows", Json::arr_f64(self.preproc.iter().map(|r| r.rows))),
            ("pre_cols", Json::arr_f64(self.preproc.iter().map(|r| r.cols))),
            ("pre_dur", Json::arr_f64(self.preproc.iter().map(|r| r.duration))),
            ("eval_dur", Json::arr_f64(self.evals.iter().map(|r| r.duration))),
        ])
    }
    fn from_json(j: &Json) -> Result<Self> {
        let job_t = j.req("job_t")?.as_f64_vec()?;
        let job_fw = j.req("job_fw")?.as_f64_vec()?;
        let job_dur = j.req("job_dur")?.as_f64_vec()?;
        if job_fw.len() != job_t.len() || job_dur.len() != job_t.len() {
            return Err(Error::Other("db: job column mismatch".into()));
        }
        let jobs = job_t
            .iter()
            .zip(&job_fw)
            .zip(&job_dur)
            .map(|((&t, &fw), &duration)| {
                let idx = fw as usize;
                if idx >= Framework::ALL.len() {
                    return Err(Error::Other(format!("db: bad framework index {idx}")));
                }
                Ok(JobRecord {
                    t,
                    framework: Framework::ALL[idx],
                    duration,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let zip3 = |a: Vec<f64>, b: Vec<f64>, c: Vec<f64>| -> Result<Vec<(f64, f64, f64)>> {
            if a.len() != b.len() || a.len() != c.len() {
                return Err(Error::Other("db: column mismatch".into()));
            }
            Ok(a.into_iter()
                .zip(b)
                .zip(c)
                .map(|((x, y), z)| (x, y, z))
                .collect())
        };
        let assets = zip3(
            j.req("asset_rows")?.as_f64_vec()?,
            j.req("asset_cols")?.as_f64_vec()?,
            j.req("asset_bytes")?.as_f64_vec()?,
        )?
        .into_iter()
        .map(|(rows, cols, bytes)| AssetRecord { rows, cols, bytes })
        .collect();
        let preproc = zip3(
            j.req("pre_rows")?.as_f64_vec()?,
            j.req("pre_cols")?.as_f64_vec()?,
            j.req("pre_dur")?.as_f64_vec()?,
        )?
        .into_iter()
        .map(|(rows, cols, duration)| PreprocRecord {
            rows,
            cols,
            duration,
        })
        .collect();
        let evals = j
            .req("eval_dur")?
            .as_f64_vec()?
            .into_iter()
            .map(|duration| EvalRecord { duration })
            .collect();
        Ok(AnalyticsDb {
            weeks: j.req("weeks")?.as_u64()? as u32,
            jobs,
            assets,
            preproc,
            evals,
        })
    }
}

// ---------------------------------------------------------------------
// experiment config tree
// ---------------------------------------------------------------------

impl JsonIo for StoreConfig {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("read_bw", Json::Num(self.read_bw)),
            ("write_bw", Json::Num(self.write_bw)),
            ("latency", Json::Num(self.latency)),
            ("tcp_overhead", Json::Num(self.tcp_overhead)),
        ])
    }
    fn from_json(j: &Json) -> Result<Self> {
        Ok(StoreConfig {
            read_bw: j.f("read_bw")?,
            write_bw: j.f("write_bw")?,
            latency: j.f("latency")?,
            tcp_overhead: j.f("tcp_overhead")?,
        })
    }
}

impl JsonIo for ClusterFailureConfig {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mtbf", self.mtbf.to_json()),
            ("mttr", self.mttr.to_json()),
            ("checkpoint_interval", Json::Num(self.checkpoint_interval)),
            ("restart_cost", Json::Num(self.restart_cost)),
        ])
    }
    fn from_json(j: &Json) -> Result<Self> {
        Ok(ClusterFailureConfig {
            mtbf: Dist::from_json(j.req("mtbf")?)?,
            mttr: Dist::from_json(j.req("mttr")?)?,
            // both knobs are optional: a bare {mtbf, mttr} model means
            // no checkpointing and free restarts
            checkpoint_interval: match j.get("checkpoint_interval") {
                Some(v) => v.as_f64()?,
                None => 0.0,
            },
            restart_cost: match j.get("restart_cost") {
                Some(v) => v.as_f64()?,
                None => 0.0,
            },
        })
    }
}

impl JsonIo for FailureModel {
    fn to_json(&self) -> Json {
        let mut fields = Vec::new();
        if let Some(f) = &self.training {
            fields.push(("training", f.to_json()));
        }
        if let Some(f) = &self.compute {
            fields.push(("compute", f.to_json()));
        }
        Json::obj(fields)
    }
    fn from_json(j: &Json) -> Result<Self> {
        let opt = |key: &str| -> Result<Option<ClusterFailureConfig>> {
            match j.get(key) {
                None | Some(Json::Null) => Ok(None),
                Some(s) => ClusterFailureConfig::from_json(s).map(Some),
            }
        };
        Ok(FailureModel {
            training: opt("training")?,
            compute: opt("compute")?,
        })
    }
}

impl JsonIo for TaskFaultConfig {
    fn to_json(&self) -> Json {
        let mut fields = Vec::new();
        if let Some(d) = &self.fault_time {
            fields.push(("fault_time", d.to_json()));
        }
        if self.timeout != 0.0 {
            fields.push(("timeout", Json::Num(self.timeout)));
        }
        if self.queue_cap != 0 {
            fields.push(("queue_cap", Json::Num(self.queue_cap as f64)));
        }
        Json::obj(fields)
    }
    fn from_json(j: &Json) -> Result<Self> {
        Ok(TaskFaultConfig {
            // every knob is optional: a bare {} is the all-off config
            fault_time: match j.get("fault_time") {
                None | Some(Json::Null) => None,
                Some(d) => Some(Dist::from_json(d)?),
            },
            timeout: match j.get("timeout") {
                Some(v) => v.as_f64()?,
                None => 0.0,
            },
            queue_cap: match j.get("queue_cap") {
                Some(v) => v.as_u64()?,
                None => 0,
            },
        })
    }
}

impl JsonIo for FaultModel {
    fn to_json(&self) -> Json {
        let mut fields = Vec::new();
        if let Some(f) = &self.training {
            fields.push(("training", f.to_json()));
        }
        if let Some(f) = &self.compute {
            fields.push(("compute", f.to_json()));
        }
        fields.push(("retry", self.retry.to_json()));
        Json::obj(fields)
    }
    fn from_json(j: &Json) -> Result<Self> {
        let opt = |key: &str| -> Result<Option<TaskFaultConfig>> {
            match j.get(key) {
                None | Some(Json::Null) => Ok(None),
                Some(s) => TaskFaultConfig::from_json(s).map(Some),
            }
        };
        Ok(FaultModel {
            training: opt("training")?,
            compute: opt("compute")?,
            retry: match j.get("retry") {
                None | Some(Json::Null) => StrategySpec::new("always"),
                Some(r) => StrategySpec::from_json(r)?,
            },
        })
    }
}

impl JsonIo for HwClass {
    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            ("slots", Json::Num(self.slots as f64)),
            ("speed", Json::Num(self.speed)),
            ("cost_per_sec", Json::Num(self.cost_per_sec)),
        ];
        if !self.fw_speed.is_empty() {
            fields.push((
                "fw_speed",
                Json::Obj(
                    self.fw_speed
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Num(*v)))
                        .collect(),
                ),
            ));
        }
        if let Some(f) = &self.failures {
            fields.push(("failures", f.to_json()));
        }
        Json::obj(fields)
    }
    fn from_json(j: &Json) -> Result<Self> {
        let mut fw_speed = Vec::new();
        match j.get("fw_speed") {
            None | Some(Json::Null) => {}
            Some(Json::Obj(fields)) => {
                for (k, v) in fields {
                    fw_speed.push((k.clone(), v.as_f64()?));
                }
            }
            Some(other) => {
                return Err(Error::Other(format!(
                    "hw class fw_speed must be an object, got {other:?}"
                )))
            }
        }
        Ok(HwClass {
            name: j.s("name")?.to_string(),
            slots: j.req("slots")?.as_usize()?,
            // speed/cost are optional: a bare {name, slots} class is the
            // homogeneous baseline
            speed: match j.get("speed") {
                Some(v) => v.as_f64()?,
                None => 1.0,
            },
            cost_per_sec: match j.get("cost_per_sec") {
                Some(v) => v.as_f64()?,
                None => 0.0,
            },
            fw_speed,
            failures: match j.get("failures") {
                None | Some(Json::Null) => None,
                Some(f) => Some(ClusterFailureConfig::from_json(f)?),
            },
        })
    }
}

impl JsonIo for HwClasses {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "training",
                Json::Arr(self.training.iter().map(|c| c.to_json()).collect()),
            ),
            (
                "compute",
                Json::Arr(self.compute.iter().map(|c| c.to_json()).collect()),
            ),
            ("placer", self.placer.to_json()),
        ])
    }
    fn from_json(j: &Json) -> Result<Self> {
        let classes = |key: &str| -> Result<Vec<HwClass>> {
            match j.get(key) {
                None | Some(Json::Null) => Ok(Vec::new()),
                Some(Json::Arr(items)) => items.iter().map(HwClass::from_json).collect(),
                Some(other) => Err(Error::Other(format!(
                    "hw_classes.{key} must be an array, got {other:?}"
                ))),
            }
        };
        Ok(HwClasses {
            training: classes("training")?,
            compute: classes("compute")?,
            placer: match j.get("placer") {
                None | Some(Json::Null) => StrategySpec::new("fastest_fit"),
                Some(p) => StrategySpec::from_json(p)?,
            },
        })
    }
}

impl JsonIo for InfraConfig {
    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("training_capacity", Json::Num(self.training_capacity as f64)),
            ("compute_capacity", Json::Num(self.compute_capacity as f64)),
            ("train_slots", Json::Num(self.train_slots as f64)),
            ("scheduler", self.scheduler.to_json()),
        ];
        // per-cluster overrides are emitted only when set, so configs
        // without them (and the config JSON embedded in existing trace
        // files) keep their exact pre-split encoding
        if let Some(s) = &self.scheduler_training {
            fields.push(("scheduler_training", s.to_json()));
        }
        if let Some(s) = &self.scheduler_compute {
            fields.push(("scheduler_compute", s.to_json()));
        }
        // same rule for failure injection: the reliable-platform default
        // emits no key at all
        if let Some(f) = &self.failures {
            fields.push(("failures", f.to_json()));
        }
        // and for hardware classes: homogeneous pools emit no key
        if let Some(hw) = &self.hw_classes {
            fields.push(("hw_classes", hw.to_json()));
        }
        // and for task faults: the fault-free default emits no key
        if let Some(f) = &self.faults {
            fields.push(("faults", f.to_json()));
        }
        fields.push(("store", self.store.to_json()));
        Json::obj(fields)
    }
    fn from_json(j: &Json) -> Result<Self> {
        // "scheduler" is canonical; "discipline" (a bare string) is the
        // pre-strategy-API encoding, still accepted
        let scheduler = match j.get("scheduler").or_else(|| j.get("discipline")) {
            Some(s) => StrategySpec::from_json(s)?,
            None => StrategySpec::new("fifo"),
        };
        let opt_spec = |key: &str| -> Result<Option<StrategySpec>> {
            match j.get(key) {
                None | Some(Json::Null) => Ok(None),
                Some(s) => StrategySpec::from_json(s).map(Some),
            }
        };
        Ok(InfraConfig {
            training_capacity: j.req("training_capacity")?.as_usize()?,
            compute_capacity: j.req("compute_capacity")?.as_usize()?,
            // optional: configs predating wide training jobs are unit-slot
            train_slots: match j.get("train_slots") {
                Some(v) => v.as_usize()?,
                None => 1,
            },
            scheduler,
            scheduler_training: opt_spec("scheduler_training")?,
            scheduler_compute: opt_spec("scheduler_compute")?,
            failures: match j.get("failures") {
                None | Some(Json::Null) => None,
                Some(f) => Some(FailureModel::from_json(f)?),
            },
            hw_classes: match j.get("hw_classes") {
                None | Some(Json::Null) => None,
                Some(h) => Some(HwClasses::from_json(h)?),
            },
            faults: match j.get("faults") {
                None | Some(Json::Null) => None,
                Some(f) => Some(FaultModel::from_json(f)?),
            },
            store: StoreConfig::from_json(j.req("store")?)?,
        })
    }
}

impl JsonIo for SynthConfig {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "framework_shares",
                Json::arr_f64(self.framework_shares.iter().cloned()),
            ),
            ("p_preprocess", Json::Num(self.p_preprocess)),
            ("p_evaluate", Json::Num(self.p_evaluate)),
            ("p_compress", Json::Num(self.p_compress)),
            ("p_harden", Json::Num(self.p_harden)),
            ("p_reevaluate", Json::Num(self.p_reevaluate)),
            ("p_transfer", Json::Num(self.p_transfer)),
            ("p_deploy", Json::Num(self.p_deploy)),
        ])
    }
    fn from_json(j: &Json) -> Result<Self> {
        let shares = j.req("framework_shares")?.as_f64_vec()?;
        if shares.len() != 5 {
            return Err(Error::Other("framework_shares must have 5 entries".into()));
        }
        Ok(SynthConfig {
            framework_shares: [shares[0], shares[1], shares[2], shares[3], shares[4]],
            p_preprocess: j.f("p_preprocess")?,
            p_evaluate: j.f("p_evaluate")?,
            p_compress: j.f("p_compress")?,
            p_harden: j.f("p_harden")?,
            p_reevaluate: j.f("p_reevaluate")?,
            p_transfer: j.f("p_transfer")?,
            p_deploy: j.f("p_deploy")?,
        })
    }
}

impl JsonIo for RuntimeViewConfig {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("enabled", Json::Bool(self.enabled)),
            ("detector_interval", Json::Num(self.detector_interval)),
            ("decay_per_day", Json::Num(self.decay_per_day)),
            ("sudden_drift_prob", Json::Num(self.sudden_drift_prob)),
            ("sudden_drift_drop", Json::Num(self.sudden_drift_drop)),
            ("trigger", self.trigger.to_json()),
            ("max_models", Json::Num(self.max_models as f64)),
        ])
    }
    fn from_json(j: &Json) -> Result<Self> {
        Ok(RuntimeViewConfig {
            enabled: j.req("enabled")?.as_bool()?,
            detector_interval: j.f("detector_interval")?,
            decay_per_day: j.f("decay_per_day")?,
            sudden_drift_prob: j.f("sudden_drift_prob")?,
            sudden_drift_drop: j.f("sudden_drift_drop")?,
            trigger: StrategySpec::from_json(j.req("trigger")?)?,
            max_models: j.req("max_models")?.as_usize()?,
        })
    }
}

impl JsonIo for ArrivalSpec {
    fn to_json(&self) -> Json {
        match self {
            ArrivalSpec::Random => Json::obj(vec![("mode", Json::Str("random".into()))]),
            ArrivalSpec::Profile => Json::obj(vec![("mode", Json::Str("profile".into()))]),
            ArrivalSpec::Poisson { mean_interarrival } => Json::obj(vec![
                ("mode", Json::Str("poisson".into())),
                ("mean_interarrival", Json::Num(*mean_interarrival)),
            ]),
            ArrivalSpec::Replay => Json::obj(vec![("mode", Json::Str("replay".into()))]),
        }
    }
    fn from_json(j: &Json) -> Result<Self> {
        Ok(match j.s("mode")? {
            "random" => ArrivalSpec::Random,
            "profile" => ArrivalSpec::Profile,
            "replay" => ArrivalSpec::Replay,
            "poisson" => ArrivalSpec::Poisson {
                mean_interarrival: j.f("mean_interarrival")?,
            },
            s => return Err(Error::Other(format!("unknown arrival spec '{s}'"))),
        })
    }
}

impl JsonIo for RetentionConfig {
    fn to_json(&self) -> Json {
        Json::obj(vec![("resolution", Json::Num(self.resolution))])
    }
    fn from_json(j: &Json) -> Result<Self> {
        Ok(RetentionConfig {
            resolution: j.f("resolution")?,
        })
    }
}

impl JsonIo for ExperimentConfig {
    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("horizon", Json::Num(self.horizon)),
            ("arrival", self.arrival.to_json()),
            ("interarrival_factor", Json::Num(self.interarrival_factor)),
            ("infra", self.infra.to_json()),
            ("synth", self.synth.to_json()),
            ("sample_interval", Json::Num(self.sample_interval)),
            ("record_traces", Json::Bool(self.record_traces)),
            ("capture_trace", Json::Bool(self.capture_trace)),
            ("runtime_view", self.runtime_view.to_json()),
            (
                "max_pipelines",
                self.max_pipelines
                    .map(|m| Json::Num(m as f64))
                    .unwrap_or(Json::Null),
            ),
        ];
        // observability knobs are emitted only when set, so pre-existing
        // configs (and the config JSON embedded in trace files) keep
        // their exact prior encoding
        if let Some(ret) = &self.retention {
            fields.push(("retention", ret.to_json()));
        }
        if self.meter {
            fields.push(("meter", Json::Bool(true)));
        }
        Json::obj(fields)
    }
    fn from_json(j: &Json) -> Result<Self> {
        Ok(ExperimentConfig {
            name: j.s("name")?.to_string(),
            seed: j.req("seed")?.as_u64()?,
            horizon: j.f("horizon")?,
            arrival: ArrivalSpec::from_json(j.req("arrival")?)?,
            interarrival_factor: j.f("interarrival_factor")?,
            infra: InfraConfig::from_json(j.req("infra")?)?,
            synth: SynthConfig::from_json(j.req("synth")?)?,
            sample_interval: j.f("sample_interval")?,
            record_traces: j.req("record_traces")?.as_bool()?,
            // optional: configs predating the trace subsystem parse as "off"
            capture_trace: match j.get("capture_trace") {
                None | Some(Json::Null) => false,
                Some(v) => v.as_bool()?,
            },
            runtime_view: RuntimeViewConfig::from_json(j.req("runtime_view")?)?,
            max_pipelines: match j.get("max_pipelines") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_u64()?),
            },
            retention: match j.get("retention") {
                None | Some(Json::Null) => None,
                Some(r) => Some(RetentionConfig::from_json(r)?),
            },
            meter: match j.get("meter") {
                None | Some(Json::Null) => false,
                Some(v) => v.as_bool()?,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg64;

    fn roundtrip<T: JsonIo + std::fmt::Debug>(v: &T) -> T {
        let text = v.to_json().to_string();
        T::from_json(&Json::parse(&text).unwrap()).unwrap()
    }

    #[test]
    fn dist_roundtrips() {
        for d in [
            Dist::Normal(Normal::new(1.0, 2.0)),
            Dist::LogNormal(LogNormal::new(-1.0, 0.15)),
            Dist::Exponential(Exponential::new(0.5)),
            Dist::Weibull(Weibull::new(1.5, 10.0)),
            Dist::ExpWeibull(ExpWeibull::new(2.0, 0.9, 40.0)),
            Dist::Pareto(Pareto::new(1.0, 1.5)),
        ] {
            assert_eq!(roundtrip(&d), d);
        }
    }

    #[test]
    fn gmm_roundtrips() {
        let mut rng = Pcg64::new(1);
        let data: Vec<[f64; 3]> = (0..200)
            .map(|_| [rng.normal(), rng.normal(), rng.normal()])
            .collect();
        let (g, _) = Gmm3::fit(&data, 3, &mut rng, 10, 1e-6).unwrap();
        let back = roundtrip(&g);
        assert_eq!(back.logw, g.logw);
        assert_eq!(back.mu, g.mu);
        assert_eq!(back.pchol, g.pchol);

        let x: Vec<f64> = (0..100).map(|_| rng.normal()).collect();
        let (g1, _) = Gmm1::fit(&x, 2, &mut rng, 10, 1e-6);
        let back = roundtrip(&g1);
        assert_eq!(back.mu, g1.mu);
    }

    #[test]
    fn config_roundtrip() {
        let mut cfg = ExperimentConfig::default();
        cfg.max_pipelines = Some(1234);
        cfg.runtime_view.trigger = StrategySpec::new("off_peak")
            .with("threshold", 0.07)
            .with("max_intensity", 0.4);
        cfg.infra.scheduler = StrategySpec::new("weighted_fair").with("weight_power", 2.0);
        let back = roundtrip(&cfg);
        assert_eq!(back.max_pipelines, Some(1234));
        assert_eq!(back.runtime_view.trigger, cfg.runtime_view.trigger);
        assert_eq!(back.infra.scheduler, cfg.infra.scheduler);
        assert_eq!(back.synth.framework_shares, cfg.synth.framework_shares);
    }

    #[test]
    fn strategy_spec_accepts_all_encodings() {
        // canonical
        let j = Json::parse(r#"{"name":"edf","params":{"slack_per_class":900}}"#).unwrap();
        let spec = StrategySpec::from_json(&j).unwrap();
        assert_eq!(spec, StrategySpec::new("edf").with("slack_per_class", 900.0));
        assert_eq!(roundtrip(&spec), spec);
        // bare string (legacy "discipline")
        let j = Json::parse(r#""sjf""#).unwrap();
        assert_eq!(StrategySpec::from_json(&j).unwrap(), StrategySpec::new("sjf"));
        // legacy trigger form with inline params
        let j = Json::parse(r#"{"policy":"off_peak","threshold":0.05,"max_intensity":0.5}"#)
            .unwrap();
        let spec = StrategySpec::from_json(&j).unwrap();
        assert_eq!(spec.name, "off_peak");
        assert_eq!(spec.get("threshold"), Some(0.05));
        assert_eq!(spec.get("max_intensity"), Some(0.5));
        // explicit null params = parameterless
        let j = Json::parse(r#"{"name":"fifo","params":null}"#).unwrap();
        assert_eq!(StrategySpec::from_json(&j).unwrap(), StrategySpec::new("fifo"));
        // no name at all
        assert!(StrategySpec::from_json(&Json::parse(r#"{"threshold":1}"#).unwrap()).is_err());
    }

    #[test]
    fn failure_config_roundtrips_and_defaults_knobs() {
        let f = ClusterFailureConfig {
            mtbf: Dist::Weibull(Weibull::new(1.2, 7200.0)),
            mttr: Dist::LogNormal(LogNormal::new(4.0, 0.5)),
            checkpoint_interval: 600.0,
            restart_cost: 45.0,
        };
        assert_eq!(roundtrip(&f), f);
        // a bare {mtbf, mttr} model parses with both knobs off
        let j = Json::parse(
            r#"{"mtbf":{"family":"exponential","lambda":0.001},
                "mttr":{"family":"exponential","lambda":0.01}}"#,
        )
        .unwrap();
        let f = ClusterFailureConfig::from_json(&j).unwrap();
        assert_eq!(f.checkpoint_interval, 0.0);
        assert_eq!(f.restart_cost, 0.0);
        // FailureModel omits unset clusters
        let m = FailureModel {
            training: Some(f),
            compute: None,
        };
        assert_eq!(roundtrip(&m), m);
        assert!(!m.to_json().to_string().contains("compute"));
    }

    #[test]
    fn fault_config_roundtrips_and_defaults_knobs() {
        let f = TaskFaultConfig {
            fault_time: Some(Dist::Weibull(Weibull::new(0.8, 5400.0))),
            timeout: 900.0,
            queue_cap: 32,
        };
        assert_eq!(roundtrip(&f), f);
        // a bare {} parses as the all-off config, and off knobs are
        // omitted on the way out
        let j = Json::parse("{}").unwrap();
        let f = TaskFaultConfig::from_json(&j).unwrap();
        assert_eq!(f, TaskFaultConfig::default());
        let text = TaskFaultConfig::transient(3600.0).to_json().to_string();
        assert!(!text.contains("timeout"), "{text}");
        assert!(!text.contains("queue_cap"), "{text}");
        // FaultModel omits unset clusters and defaults retry to always
        let m = FaultModel {
            training: None,
            compute: Some(TaskFaultConfig::transient(7200.0)),
            retry: StrategySpec::new("fixed").with("max_attempts", 3.0),
        };
        assert_eq!(roundtrip(&m), m);
        assert!(!m.to_json().to_string().contains("training"));
        let j = Json::parse(r#"{"compute":{"queue_cap":8}}"#).unwrap();
        let m = FaultModel::from_json(&j).unwrap();
        assert_eq!(m.retry, StrategySpec::new("always"));
        assert_eq!(m.compute.as_ref().map(|c| c.queue_cap), Some(8));
    }

    #[test]
    fn framework_parse() {
        assert_eq!(Framework::parse_name("sparkml").unwrap(), Framework::SparkML);
        assert!(Framework::parse_name("mxnet").is_err());
    }

    #[test]
    fn bad_shapes_rejected() {
        let j = Json::parse(r#"{"logw":[0.0],"mu":[1,2],"logsd":[0.0]}"#).unwrap();
        assert!(Gmm1::from_json(&j).is_err());
    }
}
