//! In-tree substrates replacing crates unavailable in the offline build
//! environment: JSON and binary persistence, CLI parsing, and a
//! micro-benchmark harness.

pub mod alloc;
pub mod bench;
pub mod binio;
pub mod cli;
pub mod heap4;
pub mod json;
pub mod jsonio;

pub use cli::Args;
pub use json::Json;
