//! In-tree substrates replacing crates unavailable in the offline build
//! environment: JSON persistence, CLI parsing, and a micro-benchmark
//! harness.

pub mod bench;
pub mod cli;
pub mod json;
pub mod jsonio;

pub use cli::Args;
pub use json::Json;
