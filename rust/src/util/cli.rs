//! Tiny CLI argument parser (offline environment: no clap).
//!
//! Supports `pipesim <subcommand> [<action>] --key value --flag` with
//! typed getters and defaults; unknown options are an error so typos
//! surface. The optional second positional is the sub-subcommand used by
//! grouped commands (`pipesim trace export ...`).

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed arguments: a subcommand, an optional action (second
/// positional), plus `--key [value]` options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    /// Sub-subcommand, e.g. `export` in `pipesim trace export`.
    pub action: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = it.next();
                if let Some(second) = it.peek() {
                    if !second.starts_with("--") {
                        args.action = it.next();
                    }
                }
            }
        }
        while let Some(tok) = it.next() {
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| Error::Config(format!("unexpected argument '{tok}'")))?
                .to_string();
            // a value follows unless the next token is another option
            match it.peek() {
                Some(next) if !next.starts_with("--") => {
                    let val = it.next().unwrap();
                    args.opts.insert(key, val);
                }
                _ => args.flags.push(key),
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    /// String option with default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.opts.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string option.
    pub fn get_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.opts.get(key).cloned()
    }

    /// Typed option with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        self.mark(key);
        match self.opts.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: cannot parse '{v}'"))),
        }
    }

    /// Optional typed option.
    pub fn get_parse_opt<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>> {
        self.mark(key);
        match self.opts.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| Error::Config(format!("--{key}: cannot parse '{v}'"))),
        }
    }

    /// Boolean flag (present = true).
    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key) || self.opts.get(key).map(|v| v == "true").unwrap_or(false)
    }

    /// Error on any option that no getter asked about (typo guard).
    /// Call after all getters.
    pub fn reject_unknown(&self) -> Result<()> {
        let seen = self.consumed.borrow();
        for key in self.opts.keys().chain(self.flags.iter()) {
            if !seen.iter().any(|s| s == key) {
                return Err(Error::Config(format!("unknown option --{key}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["simulate", "--days", "3.5", "--cpu", "--seed", "7"]);
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.get_parse("days", 1.0).unwrap(), 3.5);
        assert_eq!(a.get_parse("seed", 0u64).unwrap(), 7);
        assert!(a.flag("cpu"));
        assert!(!a.flag("gpu"));
        a.reject_unknown().unwrap();
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["fit"]);
        assert_eq!(a.get("db", "empirical_db.json"), "empirical_db.json");
        assert_eq!(a.get_parse("weeks", 8u32).unwrap(), 8);
        assert_eq!(a.get_opt("missing"), None);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["x", "--verbose"]);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn bad_parse_is_error() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.get_parse("n", 0u32).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let a = parse(&["x", "--typo", "1"]);
        a.get("other", "");
        assert!(a.reject_unknown().is_err());
    }

    #[test]
    fn second_positional_is_the_action() {
        let a = parse(&["trace", "export", "--out", "t.pst"]);
        assert_eq!(a.subcommand.as_deref(), Some("trace"));
        assert_eq!(a.action.as_deref(), Some("export"));
        assert_eq!(a.get("out", ""), "t.pst");
        a.reject_unknown().unwrap();
        // no action
        let a = parse(&["simulate", "--days", "1"]);
        assert_eq!(a.action, None);
    }

    #[test]
    fn third_positional_is_error() {
        assert!(Args::parse(
            ["trace", "export", "stray"].map(String::from)
        )
        .is_err());
    }
}
