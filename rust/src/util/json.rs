//! Minimal JSON: value type, writer, recursive-descent parser.
//!
//! The build environment is offline (no serde/serde_json), so PipeSim
//! carries its own JSON substrate for persistence (analytics DB, fitted
//! parameters, experiment configs, the artifact manifest). Scope: full
//! JSON except exotic number forms; numbers are f64 (every count we
//! persist is < 2^53).

use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ---------------- constructors ----------------

    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: impl IntoIterator<Item = f64>) -> Json {
        Json::Arr(xs.into_iter().map(Json::Num).collect())
    }

    pub fn from_str_slice(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // ---------------- accessors ----------------

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Object field lookup that errors with the key name.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Other(format!("json: missing field '{key}'")))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(Error::Other(format!("json: expected number, got {self:?}"))),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_f64()? as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(Error::Other(format!("json: expected bool, got {self:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::Other(format!("json: expected string, got {self:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(Error::Other(format!("json: expected array, got {self:?}"))),
        }
    }

    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Field as f64 (shorthand).
    pub fn f(&self, key: &str) -> Result<f64> {
        self.req(key)?.as_f64()
    }

    /// Field as string.
    pub fn s(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str()
    }

    // ---------------- writer ----------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        // RFC-compatible round-trip precision
                        let _ = write!(out, "{n:?}");
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---------------- parser ----------------

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Other(format!(
                "json: trailing data at byte {}",
                p.pos
            )));
        }
        Ok(v)
    }

    // ---------------- file helpers ----------------

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_string())
            .map_err(|e| Error::Other(format!("writing {}: {e}", path.display())))?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Other(format!("reading {}: {e}", path.display())))?;
        Json::parse(&text)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| Error::Other("json: unexpected end".into()))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? == b {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::Other(format!(
                "json: expected '{}' at byte {}, found '{}'",
                b as char, self.pos, self.bytes[self.pos] as char
            )))
        }
    }

    fn literal(&mut self, text: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(value)
        } else {
            Err(Error::Other(format!("json: bad literal at {}", self.pos)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(Error::Other("json: bad \\u".into()));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| Error::Other("json: bad \\u".into()))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::Other("json: bad \\u".into()))?;
                            self.pos += 4;
                            // no surrogate-pair support needed for our data
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(Error::Other("json: bad escape".into())),
                    }
                }
                _ => {
                    // collect the full UTF-8 sequence
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        return Err(Error::Other("json: truncated utf8".into()));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| Error::Other("json: invalid utf8".into()))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Other(format!("json: bad number '{text}'")))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                c => {
                    return Err(Error::Other(format!(
                        "json: expected ',' or ']', found '{}'",
                        c as char
                    )))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                c => {
                    return Err(Error::Other(format!(
                        "json: expected ',' or '}}', found '{}'",
                        c as char
                    )))
                }
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.25"] {
            let v = Json::parse(text).unwrap();
            assert_eq!(v.to_string(), text);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let v = Json::obj(vec![
            ("name", Json::Str("pipe\"sim".into())),
            ("counts", Json::arr_f64([1.0, 2.5, -3.0])),
            ("nested", Json::obj(vec![("ok", Json::Bool(true)), ("nil", Json::Null)])),
        ]);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, v);
        assert_eq!(back.s("name").unwrap(), "pipe\"sim");
        assert_eq!(back.req("counts").unwrap().as_f64_vec().unwrap(), vec![1.0, 2.5, -3.0]);
        assert!(back.req("nested").unwrap().req("ok").unwrap().as_bool().unwrap());
    }

    #[test]
    fn parses_whitespace_and_unicode() {
        let v = Json::parse(" { \"k\" : [ 1 ,\n 2 ] , \"s\": \"héllo\\u0041\" } ").unwrap();
        assert_eq!(v.req("k").unwrap().as_f64_vec().unwrap(), vec![1.0, 2.0]);
        assert_eq!(v.s("s").unwrap(), "hélloA");
    }

    #[test]
    fn scientific_numbers() {
        let v = Json::parse("[1e3, -2.5E-2, 4.0e8]").unwrap();
        assert_eq!(v.as_f64_vec().unwrap(), vec![1000.0, -0.025, 4.0e8]);
    }

    #[test]
    fn float_precision_roundtrip() {
        let x = 0.018f64;
        let v = Json::Num(x);
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(back.as_f64().unwrap(), x);
        let y = 1.0 / 3.0;
        let back = Json::parse(&Json::Num(y).to_string()).unwrap();
        assert_eq!(back.as_f64().unwrap(), y);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn missing_field_error_names_key() {
        let v = Json::parse("{\"a\": 1}").unwrap();
        let err = v.req("missing").unwrap_err().to_string();
        assert!(err.contains("missing"));
    }

    #[test]
    fn file_helpers() {
        let v = Json::obj(vec![("x", Json::Num(5.0))]);
        let path = std::env::temp_dir().join("pipesim_json_test.json");
        v.save(&path).unwrap();
        assert_eq!(Json::load(&path).unwrap(), v);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn escapes_control_chars() {
        let v = Json::Str("a\nb\tc\u{1}".into());
        let text = v.to_string();
        assert!(text.contains("\\n") && text.contains("\\t") && text.contains("\\u0001"));
        assert_eq!(Json::parse(&text).unwrap(), v);
    }
}
