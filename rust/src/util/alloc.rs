//! Counting global allocator, promoted out of `benches/bench_trace.rs`
//! so every bench and the `SimMeter` share one implementation.
//!
//! The counter tracks *allocation events* (`alloc` + `realloc`, not
//! `dealloc`), which is the quantity the zero-allocation guards assert
//! on: a hot path that performs zero allocation events holds O(1)
//! memory no matter how long it runs.
//!
//! Counting only happens when a binary opts in by installing the
//! allocator:
//!
//! ```ignore
//! use pipesim::util::alloc::CountingAlloc;
//! #[global_allocator]
//! static ALLOCATOR: CountingAlloc = CountingAlloc;
//! ```
//!
//! Rust permits a single `#[global_allocator]` per binary, so the
//! attribute lives in each bench/binary, not here. When no binary
//! installs it, [`allocs`] stays at 0 and the `SimMeter`'s
//! `alloc_events` counter reads 0 — documented as "allocator not
//! installed", never an error.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// System allocator wrapped with an allocation-event counter.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Total allocation events since process start (0 when no binary has
/// installed [`CountingAlloc`]).
pub fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}
