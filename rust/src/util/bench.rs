//! Micro-benchmark harness (offline environment: no criterion).
//!
//! Criterion-style adaptive timing: warm up, pick an iteration count that
//! fills the measurement window, run repeats, report mean/min/σ. Used by
//! every file under `benches/` (declared `harness = false`).

use std::time::{Duration, Instant};

/// One measured result.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters_per_round: u64,
    pub rounds: usize,
    pub mean: Duration,
    pub min: Duration,
    pub std_dev: Duration,
}

impl Measurement {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (min {:>12}, σ {:>10}, {} x {} iters)",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.min),
            fmt_dur(self.std_dev),
            self.rounds,
            self.iters_per_round,
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with a fixed wall-clock budget per case.
pub struct Bench {
    /// Target time per measurement round.
    pub round_budget: Duration,
    pub rounds: usize,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            round_budget: Duration::from_millis(300),
            rounds: 5,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_budget(round_budget: Duration, rounds: usize) -> Self {
        Bench {
            round_budget,
            rounds,
            results: Vec::new(),
        }
    }

    /// Measure `f`, which performs ONE logical iteration per call.
    pub fn bench(&mut self, name: impl Into<String>, mut f: impl FnMut()) -> &Measurement {
        // warmup + calibration
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(20));
        let iters = (self.round_budget.as_nanos() / once.as_nanos()).clamp(1, 50_000_000) as u64;
        let mut round_means = Vec::with_capacity(self.rounds);
        for _ in 0..self.rounds {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            round_means.push(t.elapsed().as_secs_f64() / iters as f64);
        }
        let mean = round_means.iter().sum::<f64>() / round_means.len() as f64;
        let min = round_means.iter().cloned().fold(f64::INFINITY, f64::min);
        let var = round_means
            .iter()
            .map(|m| (m - mean) * (m - mean))
            .sum::<f64>()
            / round_means.len() as f64;
        let m = Measurement {
            name: name.into(),
            iters_per_round: iters,
            rounds: self.rounds,
            mean: Duration::from_secs_f64(mean),
            min: Duration::from_secs_f64(min),
            std_dev: Duration::from_secs_f64(var.sqrt()),
        };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Measure a one-shot (non-repeatable) workload: runs once per round.
    pub fn bench_once(&mut self, name: impl Into<String>, mut f: impl FnMut()) -> &Measurement {
        let mut round_means = Vec::with_capacity(self.rounds);
        for _ in 0..self.rounds {
            let t = Instant::now();
            f();
            round_means.push(t.elapsed().as_secs_f64());
        }
        let mean = round_means.iter().sum::<f64>() / round_means.len() as f64;
        let min = round_means.iter().cloned().fold(f64::INFINITY, f64::min);
        let var = round_means
            .iter()
            .map(|m| (m - mean) * (m - mean))
            .sum::<f64>()
            / round_means.len() as f64;
        let m = Measurement {
            name: name.into(),
            iters_per_round: 1,
            rounds: self.rounds,
            mean: Duration::from_secs_f64(mean),
            min: Duration::from_secs_f64(min),
            std_dev: Duration::from_secs_f64(var.sqrt()),
        };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

/// Opaque value sink preventing the optimizer from deleting benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_simple_work() {
        let mut b = Bench::with_budget(Duration::from_millis(5), 3);
        let mut acc = 0u64;
        let m = b
            .bench("add", || {
                acc = black_box(acc.wrapping_add(1));
            })
            .clone();
        assert!(m.mean.as_nanos() > 0);
        assert!(m.iters_per_round >= 1);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn bench_once_counts_rounds() {
        let mut b = Bench::with_budget(Duration::from_millis(1), 4);
        let mut runs = 0;
        b.bench_once("once", || runs += 1);
        assert_eq!(runs, 4);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}
