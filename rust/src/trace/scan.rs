//! Streamed `.pst` reading: iterate a trace file record-by-record.
//!
//! [`Trace::load`](super::Trace::load) materializes the whole event
//! `Vec` — fine for a day of simulated time, hopeless for the
//! year-scale captures `StreamingPstSink` exists to produce (hundreds
//! of millions of events would need tens of GB of RAM just to be
//! *counted*). [`TraceScanner`] instead decodes one record at a time
//! straight off a `BufReader`, holding only the string table, the
//! metadata, and one record's state — O(1) in trace length, the read
//! twin of the sink's write-side bound.
//!
//! Both layouts are supported and yield the identical event sequence:
//!
//! * **buffered** (versions 1/2/4/5, reserved = 0): string table and
//!   meta precede the records, so the scanner parses them on open and
//!   then streams the body forward.
//! * **streamed** (version 3, or 4+ with the reserved streamed flag):
//!   the scanner seeks the fixed-size tail, parses the footer (string
//!   table + meta + count), then seeks back to the first record and
//!   streams the body — two seeks total, never a full-file read.
//!
//! Decoding is byte-identical to the buffered loader: both call the
//! same `codec::decode_kind`, generic over
//! [`BinRead`](crate::util::binio::BinRead). Truncated or corrupt
//! files surface as an `Err` item from the iterator (and the scanner
//! fuses afterwards); a partial capture can never summarize silently.

use std::fs::File;
use std::io::{BufReader, Read, Seek, SeekFrom};
use std::path::Path;

use crate::error::{Error, Result};
use crate::util::binio::{BinRead, ByteReader, InternTable};

use super::codec::{
    decode_kind, decode_meta, FORMAT_VERSION, MAGIC, STREAMED_FLAG, STREAM_VERSION, TAIL_MAGIC,
};
use super::{TraceEvent, TraceMeta};

/// Header bytes (magic + version + reserved) — the offset of either the
/// string table (buffered) or the first record (streamed).
const HEADER: u64 = 8;
/// Tail bytes of a streamed file: u64 footer offset + `TAIL_MAGIC`.
const TAIL: u64 = 12;

/// Byte-counting buffered reader over the trace file; implements
/// [`BinRead`] so the shared record decoder runs directly against it.
struct FileSource {
    inner: BufReader<File>,
    pos: u64,
}

impl FileSource {
    fn read_exact(&mut self, buf: &mut [u8]) -> Result<()> {
        self.inner
            .read_exact(buf)
            .map_err(|e| Error::Other(format!("trace scan: read at offset {}: {e}", self.pos)))?;
        self.pos += buf.len() as u64;
        Ok(())
    }

    /// Length-prefixed UTF-8 string, with the allocation bounded by
    /// `cap` (the file length): a corrupt prefix can never drive an
    /// allocation larger than the input itself.
    fn str_owned(&mut self, cap: u64) -> Result<String> {
        let n = self.varint()?;
        if n > cap {
            return Err(Error::Other(format!(
                "trace scan: string length {n} exceeds file size {cap}"
            )));
        }
        let mut buf = vec![0u8; n as usize];
        self.read_exact(&mut buf)?;
        String::from_utf8(buf).map_err(|_| Error::Other("trace scan: invalid utf8".into()))
    }
}

impl BinRead for FileSource {
    fn u8(&mut self) -> Result<u8> {
        let mut b = [0u8; 1];
        self.read_exact(&mut b)?;
        Ok(b[0])
    }

    fn f64(&mut self) -> Result<f64> {
        let mut b = [0u8; 8];
        self.read_exact(&mut b)?;
        Ok(f64::from_bits(u64::from_le_bytes(b)))
    }
}

/// Record-by-record `.pst` reader; see the module docs. Construct with
/// [`TraceScanner::open`], consume as an iterator of
/// `Result<TraceEvent>`.
pub struct TraceScanner {
    src: FileSource,
    names: Vec<String>,
    meta: TraceMeta,
    version: u16,
    /// Total records the file claims (count prefix or footer).
    total: u64,
    remaining: u64,
    /// Streamed layout: absolute offset where the record body ends (the
    /// footer starts); buffered: the file length. Every record must
    /// finish at or before it.
    body_end: u64,
    prev_bits: u64,
    /// Set after the first `Err` item or the end-of-body check, so the
    /// iterator fuses instead of re-reporting forever.
    done: bool,
}

impl TraceScanner {
    /// Open `path` and parse everything *except* the event records:
    /// header, string table, metadata, and the event count — from the
    /// front (buffered layout) or the footer (streamed layout). The
    /// returned scanner is positioned at the first record.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let file = File::open(path)
            .map_err(|e| Error::Other(format!("opening trace {}: {e}", path.display())))?;
        let file_len = file
            .metadata()
            .map_err(|e| Error::Other(format!("stat trace {}: {e}", path.display())))?
            .len();
        let mut src = FileSource {
            inner: BufReader::new(file),
            pos: 0,
        };
        let mut head = [0u8; HEADER as usize];
        src.read_exact(&mut head)?;
        let (version, reserved) = ByteReader::new(&head).check_header_range_with_reserved(
            MAGIC,
            1,
            FORMAT_VERSION,
            "trace",
        )?;
        let streamed =
            version == STREAM_VERSION || (version > STREAM_VERSION && reserved == STREAMED_FLAG);
        if streamed {
            Self::open_streamed(src, file_len, version)
        } else {
            Self::open_buffered(src, file_len, version)
        }
    }

    /// Buffered layout: string table, meta, and count precede the
    /// records, so parse them forward off the stream.
    fn open_buffered(mut src: FileSource, file_len: u64, version: u16) -> Result<Self> {
        // string table: every entry costs >= 1 byte, so the count is
        // bounded by the file length (same guard as the slice reader)
        let n_names = src.varint()?;
        if n_names > file_len {
            return Err(Error::Other(format!(
                "trace scan: string table claims {n_names} entries in a {file_len}-byte file"
            )));
        }
        let mut names = Vec::with_capacity(n_names as usize);
        for _ in 0..n_names {
            names.push(src.str_owned(file_len)?);
        }
        // meta block (codec::encode_meta layout: ids into the table)
        let meta = {
            let name = lookup_owned(&names, src.varint()?)?;
            let seed = src.varint()?;
            let horizon = src.f64()?;
            let config_json = lookup_owned(&names, src.varint()?)?;
            let n_extra = src.varint()?;
            if n_extra > file_len {
                return Err(Error::Other(format!(
                    "trace scan: meta claims {n_extra} extra pairs in a {file_len}-byte file"
                )));
            }
            let mut extra = Vec::with_capacity(n_extra as usize);
            for _ in 0..n_extra {
                let k = lookup_owned(&names, src.varint()?)?;
                let v = lookup_owned(&names, src.varint()?)?;
                extra.push((k, v));
            }
            TraceMeta {
                name,
                seed,
                horizon,
                config_json,
                extra,
            }
        };
        let total = src.varint()?;
        // a record costs >= 3 bytes (time varint + tag + payload)
        if total.saturating_mul(3) > file_len {
            return Err(Error::Other(format!(
                "trace scan: count claims {total} events, file holds {file_len} bytes"
            )));
        }
        Ok(TraceScanner {
            src,
            names,
            meta,
            version,
            total,
            remaining: total,
            body_end: file_len,
            prev_bits: 0,
            done: false,
        })
    }

    /// Streamed layout: seek the tail for the footer offset, parse the
    /// footer (it is small — names, meta, count), then seek back to the
    /// first record.
    fn open_streamed(mut src: FileSource, file_len: u64, version: u16) -> Result<Self> {
        if file_len < HEADER + TAIL {
            return Err(Error::Other(format!(
                "trace: streamed file of {file_len} bytes is shorter than header + tail"
            )));
        }
        let seek = |src: &mut FileSource, to: u64| -> Result<()> {
            src.inner
                .seek(SeekFrom::Start(to))
                .map_err(|e| Error::Other(format!("trace scan: seek to {to}: {e}")))?;
            src.pos = to;
            Ok(())
        };
        seek(&mut src, file_len - TAIL)?;
        let mut tail = [0u8; TAIL as usize];
        src.read_exact(&mut tail)?;
        if &tail[8..] != TAIL_MAGIC {
            return Err(Error::Other(
                "trace: streamed file has no footer tail (writer never finalized?)".into(),
            ));
        }
        let off = u64::from_le_bytes(tail[..8].try_into().expect("8-byte slice"));
        if off < HEADER || off > file_len - TAIL {
            return Err(Error::Other(format!(
                "trace: footer offset {off} outside the file body ({file_len} bytes)"
            )));
        }
        // the footer is names + meta + count — bounded and small, so a
        // single in-memory parse through the slice readers is exact
        seek(&mut src, off)?;
        let mut footer = vec![0u8; (file_len - TAIL - off) as usize];
        src.read_exact(&mut footer)?;
        let mut f = ByteReader::new(&footer);
        let names = InternTable::read(&mut f)?;
        let meta = decode_meta(&mut f, &names)?;
        let total = f.varint()?;
        f.expect_eof("trace footer")?;
        if total.saturating_mul(3) > off - HEADER {
            return Err(Error::Other(format!(
                "trace: footer claims {total} events, body holds {} bytes",
                off - HEADER
            )));
        }
        seek(&mut src, HEADER)?;
        Ok(TraceScanner {
            src,
            names,
            meta,
            version,
            total,
            remaining: total,
            body_end: off,
            prev_bits: 0,
            done: false,
        })
    }

    /// The capture's metadata (same content a full `Trace::load` gets).
    pub fn meta(&self) -> &TraceMeta {
        &self.meta
    }

    /// The format version stamped in the file header.
    pub fn version(&self) -> u16 {
        self.version
    }

    /// Total records the file claims to hold.
    pub fn events(&self) -> u64 {
        self.total
    }

    /// Stream the whole file to JSON-lines (meta line first, one event
    /// object per line) — the streamed twin of [`Trace::to_jsonl`]
    /// (byte-identical output for buffered captures), O(1) in trace
    /// length. Returns the number of event lines written.
    ///
    /// [`Trace::to_jsonl`]: super::Trace::to_jsonl
    pub fn write_jsonl<W: std::io::Write>(mut self, w: &mut W) -> Result<u64> {
        use super::codec::{jsonl_event_line, jsonl_meta_line};
        let io_err = |e: std::io::Error| Error::Other(format!("trace jsonl: write: {e}"));
        writeln!(w, "{}", jsonl_meta_line(&self.meta, self.version, self.total)).map_err(io_err)?;
        let mut n = 0u64;
        for ev in &mut self {
            writeln!(w, "{}", jsonl_event_line(&ev?)).map_err(io_err)?;
            n += 1;
        }
        w.flush().map_err(io_err)?;
        Ok(n)
    }

    fn next_event(&mut self) -> Result<Option<TraceEvent>> {
        if self.remaining == 0 {
            // the body must end exactly where the count said it would —
            // trailing bytes mean a corrupt or concatenated file
            if self.src.pos != self.body_end {
                return Err(Error::Other(format!(
                    "trace scan: {} trailing bytes after the last record",
                    self.body_end.saturating_sub(self.src.pos)
                )));
            }
            return Ok(None);
        }
        let bits = self.prev_bits ^ self.src.varint()?;
        self.prev_bits = bits;
        let kind = decode_kind(&mut self.src, &self.names, self.version)?;
        if self.src.pos > self.body_end {
            return Err(Error::Other(
                "trace scan: record runs past the end of the body".into(),
            ));
        }
        self.remaining -= 1;
        Ok(Some(TraceEvent {
            t: f64::from_bits(bits),
            kind,
        }))
    }
}

impl Iterator for TraceScanner {
    type Item = Result<TraceEvent>;

    fn next(&mut self) -> Option<Result<TraceEvent>> {
        if self.done {
            return None;
        }
        match self.next_event() {
            Ok(Some(ev)) => Some(Ok(ev)),
            Ok(None) => {
                self.done = true;
                None
            }
            Err(e) => {
                self.done = true;
                Some(Err(e))
            }
        }
    }
}

/// Owned variant of the codec's id lookup (the scanner keeps the table
/// alive for record decoding, so meta strings are copied out).
fn lookup_owned(names: &[String], id: u64) -> Result<String> {
    super::codec::lookup(names, id).map(str::to_string)
}

#[cfg(test)]
mod tests {
    use super::super::{Trace, TraceEventKind};
    use super::*;
    use crate::model::{Framework, ResourceKind, TaskType};
    use crate::trace::stream::StreamingPstSink;
    use crate::trace::TraceSink;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("pipesim_scan_{tag}_{}.pst", std::process::id()))
    }

    fn meta() -> TraceMeta {
        TraceMeta {
            name: "scan-test".into(),
            seed: 11,
            horizon: 5000.0,
            config_json: r#"{"name":"scan-test"}"#.into(),
            extra: vec![("scheduler".into(), "fifo".into())],
        }
    }

    fn sample_events() -> Vec<TraceEvent> {
        let e = |t, kind| TraceEvent { t, kind };
        vec![
            e(0.0, TraceEventKind::ArrivalGapDrawn { gap: 0.25 }),
            e(
                0.25,
                TraceEventKind::PipelineArrival {
                    pid: 0,
                    framework: Framework::PyTorch,
                    n_tasks: 3,
                    priority: 1.0,
                    retrain_of: None,
                },
            ),
            e(
                0.25,
                TraceEventKind::TaskQueued {
                    pid: 0,
                    task: TaskType::Train,
                    resource: ResourceKind::Training,
                },
            ),
            e(
                9.5,
                TraceEventKind::TaskPlaced {
                    pid: 0,
                    task: TaskType::Train,
                    resource: ResourceKind::Training,
                    class: 1,
                    slots: 2,
                },
            ),
            e(
                40.0,
                TraceEventKind::PipelineDone {
                    pid: 0,
                    makespan: 39.75,
                    total_wait: 2.5,
                    truncated: false,
                },
            ),
        ]
    }

    #[test]
    fn scans_buffered_files_to_the_same_events_as_load() {
        let path = tmp("buffered");
        let trace = Trace {
            meta: meta(),
            events: sample_events(),
        };
        trace.save(&path).unwrap();
        let mut scan = TraceScanner::open(&path).unwrap();
        assert_eq!(scan.meta(), &meta());
        assert_eq!(scan.events(), 5);
        assert_eq!(scan.version(), 5, "TaskPlaced needs v5");
        let events: Result<Vec<TraceEvent>> = (&mut scan).collect();
        assert_eq!(events.unwrap(), trace.events);
        // fused after completion
        assert!(scan.next().is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn scans_streamed_files_without_loading_the_body() {
        let path = tmp("streamed");
        let mut sink = StreamingPstSink::create(&path, &meta()).unwrap();
        for ev in sample_events() {
            sink.record(&ev);
        }
        sink.finish().unwrap();
        let scan = TraceScanner::open(&path).unwrap();
        assert_eq!(scan.meta(), &meta());
        assert_eq!(scan.events(), 5);
        let events: Result<Vec<TraceEvent>> = scan.collect();
        assert_eq!(events.unwrap(), sample_events());
        // and the scan agrees with the materializing loader exactly
        let loaded = Trace::load(&path).unwrap();
        assert_eq!(loaded.events, sample_events());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn streamed_jsonl_matches_the_buffered_export() {
        let path = tmp("jsonl");
        let trace = Trace {
            meta: meta(),
            events: sample_events(),
        };
        trace.save(&path).unwrap();
        let mut out = Vec::new();
        let n = TraceScanner::open(&path)
            .unwrap()
            .write_jsonl(&mut out)
            .unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(n, 5);
        assert_eq!(String::from_utf8(out).unwrap(), trace.to_jsonl());
    }

    #[test]
    fn empty_traces_scan_cleanly() {
        let path = tmp("empty");
        let trace = Trace {
            meta: meta(),
            events: Vec::new(),
        };
        trace.save(&path).unwrap();
        let mut scan = TraceScanner::open(&path).unwrap();
        assert_eq!(scan.events(), 0);
        assert!(scan.next().is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn unfinalized_streamed_files_are_rejected_at_open() {
        let path = tmp("unfinalized");
        let sink = StreamingPstSink::create(&path, &meta()).unwrap();
        drop(sink); // never finished: no footer tail
        let err = TraceScanner::open(&path).unwrap_err();
        assert!(err.to_string().contains("footer"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_bodies_surface_as_err_items() {
        let path = tmp("truncated");
        let trace = Trace {
            meta: meta(),
            events: sample_events(),
        };
        let bytes = trace.to_bytes();
        std::fs::write(&path, &bytes[..bytes.len() - 4]).unwrap();
        let scan = TraceScanner::open(&path).unwrap();
        let items: Vec<Result<TraceEvent>> = scan.collect();
        assert!(items.last().unwrap().is_err(), "truncation must surface");
        // earlier records still decoded
        assert!(items[0].is_ok());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_files_fail_at_open() {
        assert!(TraceScanner::open("/nonexistent/nope.pst").is_err());
    }
}
