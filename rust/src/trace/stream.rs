//! File-backed incremental `.pst` writer: memory-flat captures.
//!
//! [`MemorySink`](super::MemorySink) buffers every event until the run
//! ends — fine for a day, fatal for the year-scale horizons the paper's
//! operational studies need (hundreds of millions of events).
//! [`StreamingPstSink`] instead writes each record to disk the moment it
//! is emitted, in the exact encoding of the buffered codec, and
//! finalizes the string table + metadata in a *footer* when the run
//! completes (the streamed layout, format version
//! [`STREAM_VERSION`](super::codec::STREAM_VERSION) — see
//! [`codec`](super::codec); if a recorded event needs a newer format
//! version, e.g. the failure-injection records of version 4, the
//! header is patched in place at close, keeping failure-free captures
//! byte-identical to v3 files). Resident state is O(1) in trace length:
//! the intern table (a few dozen task/framework/resource names plus the
//! metadata strings), one record's encode scratch, and the `BufWriter`
//! block — a bound the `bench_trace` counting-allocator guard enforces.
//!
//! Inject one per run via `Experiment::with_sink` (capture turns on,
//! the sink drains empty, so the result carries metadata but no
//! buffered events), or let `sweep --trace-dir` construct one per cell.
//! The metadata must be supplied up front — build it with
//! `ExperimentConfig::trace_meta()`, the same constructor the in-memory
//! capture path uses, so a streamed file and a buffered capture of the
//! same `(config, seed)` decode to identical [`Trace`](super::Trace)s.
//!
//! IO errors on the hot path are *latched*, not panicked: `record` is
//! infallible by contract, so the first failure stops further writes
//! and surfaces from [`TraceSink::finish`] at end of run.
//!
//! The footer is written **only** by `finish` — never on drop. A sink
//! abandoned mid-run (the simulation errored, a sweep worker
//! unwound) leaves a file without the tail, which the decoder rejects
//! loudly; a partial capture can never masquerade as a complete one.

use std::fs::File;
use std::io::{BufWriter, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::binio::{ByteWriter, InternTable};

use super::codec::{
    encode_kind, encode_meta, kind_min_version, MAGIC, STREAMED_FLAG, STREAM_VERSION, TAIL_MAGIC,
};
use super::{TraceEvent, TraceMeta, TraceSink};

/// Header bytes preceding the record stream (magic + version +
/// reserved) — also the byte offset of the first record.
const HEADER_BYTES: u64 = 8;

/// A [`TraceSink`] that streams the binary trace format to a file as
/// events arrive. See the module docs for the layout and the O(1)
/// memory contract.
pub struct StreamingPstSink {
    path: PathBuf,
    out: Option<BufWriter<File>>,
    tab: InternTable,
    /// Meta block encoded at construction (interned first, mirroring
    /// the buffered encoder's table order); flushed into the footer.
    meta: Vec<u8>,
    /// Per-record encode scratch, reused — the only hot-path buffer.
    scratch: ByteWriter,
    prev_bits: u64,
    events: u64,
    /// Record-stream bytes written so far (the footer offset is
    /// `HEADER_BYTES + body_bytes`).
    body_bytes: u64,
    /// Highest format version any recorded event requires (per
    /// `codec::kind_min_version`). The header is stamped
    /// [`STREAM_VERSION`] at create; if a record needs a newer version
    /// (failure-injection tags need 4), `close` patches the header to
    /// that version with the [`STREAMED_FLAG`] reserved word — so
    /// failure-free captures stay byte-identical to version-3 files.
    needed: u16,
    /// First IO error, latched; surfaced by [`TraceSink::finish`].
    err: Option<String>,
    finished: bool,
}

impl StreamingPstSink {
    /// Create `path` (truncating any existing file) and write the
    /// streamed-layout header. `meta` is everything the footer will
    /// carry besides the event count — pass
    /// `ExperimentConfig::trace_meta()` so streamed and buffered
    /// captures of the same run are interchangeable.
    pub fn create(path: impl Into<PathBuf>, meta: &TraceMeta) -> Result<Self> {
        let path = path.into();
        let file = File::create(&path)
            .map_err(|e| Error::Other(format!("creating trace {}: {e}", path.display())))?;
        let mut out = BufWriter::new(file);
        let mut head = ByteWriter::new();
        head.header(MAGIC, STREAM_VERSION);
        debug_assert_eq!(head.len() as u64, HEADER_BYTES);
        out.write_all(head.as_slice())
            .map_err(|e| Error::Other(format!("writing trace {}: {e}", path.display())))?;
        let mut tab = InternTable::new();
        let mut mw = ByteWriter::new();
        encode_meta(&mut mw, &mut tab, meta);
        Ok(StreamingPstSink {
            path,
            out: Some(out),
            tab,
            meta: mw.into_bytes(),
            scratch: ByteWriter::new(),
            prev_bits: 0,
            events: 0,
            body_bytes: 0,
            needed: 1,
            err: None,
            finished: false,
        })
    }

    /// The file being written.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Records streamed so far.
    pub fn events_written(&self) -> u64 {
        self.events
    }

    /// Write the footer (string table + meta + event count) and the
    /// fixed-size tail, then flush. Idempotent; invoked by
    /// [`TraceSink::finish`] at end of run, which is where a latched
    /// mid-run IO error finally surfaces. Deliberately *not* run on
    /// drop: only a run that reached its orderly end may stamp the
    /// tail that marks the capture complete.
    fn close(&mut self) -> Result<()> {
        if self.finished {
            return Ok(());
        }
        self.finished = true;
        let mut out = self.out.take().expect("sink open until first close");
        if let Some(e) = self.err.take() {
            return Err(Error::Other(e));
        }
        let mut f = ByteWriter::new();
        self.tab.write(&mut f);
        f.bytes(&self.meta);
        f.varint(self.events);
        f.u64(HEADER_BYTES + self.body_bytes);
        f.bytes(TAIL_MAGIC);
        out.write_all(f.as_slice())
            .and_then(|()| out.flush())
            .and_then(|()| {
                // a record needed a newer version than the v3 header
                // stamped at create (failure-injection tags need v4):
                // rewrite the version + reserved words in place. The
                // buffer is flushed, so writing through the raw file is
                // safe; the streamed flag tells the decoder this v4+
                // file is the footer-offset layout, not the buffered
                // one.
                if self.needed > STREAM_VERSION {
                    let file = out.get_mut();
                    file.seek(SeekFrom::Start(4))?;
                    file.write_all(&self.needed.to_le_bytes())?;
                    file.write_all(&STREAMED_FLAG.to_le_bytes())?;
                    file.flush()?;
                }
                Ok(())
            })
            .map_err(|e| Error::Other(format!("finalizing trace {}: {e}", self.path.display())))
    }
}

impl TraceSink for StreamingPstSink {
    fn record(&mut self, ev: &TraceEvent) {
        if self.err.is_some() || self.finished {
            return;
        }
        let bits = ev.t.to_bits();
        self.needed = self.needed.max(kind_min_version(&ev.kind));
        self.scratch.clear();
        self.scratch.varint(bits ^ self.prev_bits);
        encode_kind(&mut self.scratch, &mut self.tab, &ev.kind);
        self.prev_bits = bits;
        self.events += 1;
        let out = self.out.as_mut().expect("sink open while recording");
        match out.write_all(self.scratch.as_slice()) {
            Ok(()) => self.body_bytes += self.scratch.len() as u64,
            Err(e) => {
                self.err = Some(format!(
                    "streaming trace {}: {e} (after {} events)",
                    self.path.display(),
                    self.events
                ));
            }
        }
    }

    fn finish(&mut self) -> Result<()> {
        self.close()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Trace, TraceEventKind};
    use super::*;
    use crate::model::{Framework, ResourceKind, TaskType};

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("pipesim_stream_{tag}_{}.pst", std::process::id()))
    }

    fn meta() -> TraceMeta {
        TraceMeta {
            name: "stream-test".into(),
            seed: 7,
            horizon: 1000.0,
            config_json: r#"{"name":"stream-test"}"#.into(),
            extra: vec![("scheduler".into(), "fifo".into())],
        }
    }

    fn sample_events() -> Vec<TraceEvent> {
        let e = |t, kind| TraceEvent { t, kind };
        vec![
            e(0.0, TraceEventKind::ArrivalGapDrawn { gap: 1.0 / 3.0 }),
            e(
                1.0 / 3.0,
                TraceEventKind::PipelineArrival {
                    pid: 0,
                    framework: Framework::TensorFlow,
                    n_tasks: 4,
                    priority: 2.0,
                    retrain_of: None,
                },
            ),
            e(
                0.5,
                TraceEventKind::TaskQueued {
                    pid: 0,
                    task: TaskType::Train,
                    resource: ResourceKind::Training,
                },
            ),
            e(
                9.0,
                TraceEventKind::TaskPreempted {
                    pid: 0,
                    task: TaskType::Train,
                    resource: ResourceKind::Training,
                    by: 1,
                    remaining: 4.25,
                },
            ),
            e(
                12.0,
                TraceEventKind::PipelineDone {
                    pid: 0,
                    makespan: 11.666_7,
                    total_wait: 3.0,
                    truncated: false,
                },
            ),
        ]
    }

    #[test]
    fn streamed_file_decodes_to_the_logical_trace() {
        let path = tmp("roundtrip");
        let mut sink = StreamingPstSink::create(&path, &meta()).unwrap();
        for ev in sample_events() {
            sink.record(&ev);
        }
        assert_eq!(sink.events_written(), 5);
        assert_eq!(sink.path(), path.as_path());
        sink.finish().unwrap();
        // finish is idempotent
        sink.finish().unwrap();
        let loaded = Trace::load(&path).unwrap();
        assert_eq!(loaded.meta, meta());
        assert_eq!(loaded.events, sample_events());
        // the streamed file stamps the streamed version on the wire
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(
            u16::from_le_bytes([bytes[4], bytes[5]]),
            STREAM_VERSION
        );
        // ... while re-encoding the decoded trace yields a buffered file
        // with the same logical content (lowest sufficient version)
        let rebuf = Trace::from_bytes(&loaded.to_bytes()).unwrap();
        assert_eq!(rebuf, loaded);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn failure_records_patch_the_header_to_v4_streamed() {
        let path = tmp("v4");
        let mut sink = StreamingPstSink::create(&path, &meta()).unwrap();
        let mut events = sample_events();
        events.push(TraceEvent {
            t: 20.0,
            kind: TraceEventKind::SlotFailed {
                resource: ResourceKind::Training,
                offline: 1,
            },
        });
        events.push(TraceEvent {
            t: 25.0,
            kind: TraceEventKind::SlotRepaired {
                resource: ResourceKind::Training,
                offline: 0,
                downtime: 5.0,
            },
        });
        for ev in &events {
            sink.record(ev);
        }
        sink.finish().unwrap();
        // header: version 4, reserved = streamed flag
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), 4);
        assert_eq!(u16::from_le_bytes([bytes[6], bytes[7]]), STREAMED_FLAG);
        // and it decodes to the logical trace, same as a buffered capture
        let loaded = Trace::load(&path).unwrap();
        assert_eq!(loaded.events, events);
        let rebuf = Trace::from_bytes(&loaded.to_bytes()).unwrap();
        assert_eq!(rebuf, loaded);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fault_records_patch_the_header_to_v6_streamed() {
        let path = tmp("v6");
        let mut sink = StreamingPstSink::create(&path, &meta()).unwrap();
        let mut events = sample_events();
        events.push(TraceEvent {
            t: 20.0,
            kind: TraceEventKind::TaskFailed {
                pid: 0,
                task: TaskType::Train,
                resource: ResourceKind::Training,
                attempt: 1,
                elapsed: 8.0,
            },
        });
        events.push(TraceEvent {
            t: 20.0,
            kind: TraceEventKind::TaskRetried {
                pid: 0,
                task: TaskType::Train,
                resource: ResourceKind::Training,
                attempt: 1,
                delay: 30.0,
            },
        });
        events.push(TraceEvent {
            t: 80.0,
            kind: TraceEventKind::PipelineAbandoned {
                pid: 0,
                attempts: 2,
                makespan: 79.666_7,
            },
        });
        for ev in &events {
            sink.record(ev);
        }
        sink.finish().unwrap();
        // header: version 6, reserved = streamed flag
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), 6);
        assert_eq!(u16::from_le_bytes([bytes[6], bytes[7]]), STREAMED_FLAG);
        // and it decodes to the logical trace, same as a buffered capture
        let loaded = Trace::load(&path).unwrap();
        assert_eq!(loaded.events, events);
        let rebuf = Trace::from_bytes(&loaded.to_bytes()).unwrap();
        assert_eq!(rebuf, loaded);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_stream_roundtrips() {
        let path = tmp("empty");
        let mut sink = StreamingPstSink::create(&path, &meta()).unwrap();
        sink.finish().unwrap();
        let loaded = Trace::load(&path).unwrap();
        assert!(loaded.is_empty());
        assert_eq!(loaded.meta, meta());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn abandoned_sink_leaves_an_unfinalized_file_that_fails_loudly() {
        // a sink dropped without finish (the run errored or unwound)
        // must NOT stamp the completion tail: a partial capture may
        // never decode as a complete one
        let path = tmp("abandoned");
        let mut sink = StreamingPstSink::create(&path, &meta()).unwrap();
        for ev in sample_events() {
            sink.record(&ev);
        }
        drop(sink);
        let err = Trace::load(&path).unwrap_err();
        assert!(err.to_string().contains("footer"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_streamed_files_fail_loudly() {
        let path = tmp("trunc");
        let mut sink = StreamingPstSink::create(&path, &meta()).unwrap();
        for ev in sample_events() {
            sink.record(&ev);
        }
        sink.finish().unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::remove_file(&path).ok();
        // chop the tail: "writer never finalized"
        let err = Trace::from_bytes(&bytes[..bytes.len() - 12]).unwrap_err();
        assert!(err.to_string().contains("footer"), "{err}");
        // chop mid-body: the tail (and with it the footer) is gone too
        assert!(Trace::from_bytes(&bytes[..20]).is_err());
        // corrupt the footer offset past the tail
        let mut bad = bytes.clone();
        let off_pos = bad.len() - 12;
        bad[off_pos..off_pos + 8].copy_from_slice(&(u64::MAX).to_le_bytes());
        let err = Trace::from_bytes(&bad).unwrap_err();
        assert!(err.to_string().contains("offset"), "{err}");
        // inflate the event count in the footer: body can't hold them.
        // Rebuild the footer with a huge count by appending a fresh tail
        // over a shortened body window — cheaper: flip the count varint.
        // The count (5) is the last footer byte before the tail.
        let mut bad = bytes.clone();
        let count_pos = bad.len() - 13;
        assert_eq!(bad[count_pos], 5, "single-byte varint count");
        bad[count_pos] = 0x7f; // claims 127 events
        let err = Trace::from_bytes(&bad).unwrap_err();
        assert!(err.to_string().contains("claims"), "{err}");
    }

    #[test]
    fn create_rejects_unwritable_paths() {
        // a directory path cannot be created as a file
        let dir = std::env::temp_dir();
        assert!(StreamingPstSink::create(&dir, &meta()).is_err());
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn record_io_errors_latch_and_surface_at_finish() {
        // /dev/full accepts opens but fails every write with ENOSPC:
        // the first BufWriter flush inside record() trips it, the error
        // latches (later records are dropped, the counter freezes), and
        // finish() surfaces it instead of stamping a completion tail
        let mut sink = StreamingPstSink::create("/dev/full", &meta()).unwrap();
        let evs = sample_events();
        // push well past the BufWriter block size to force flushes
        for _ in 0..2000 {
            for ev in &evs {
                sink.record(ev);
            }
        }
        let at_latch = sink.events_written();
        assert!(at_latch < 10_000, "no write ever failed on /dev/full");
        sink.record(&evs[0]);
        assert_eq!(sink.events_written(), at_latch, "post-latch record not dropped");
        let err = sink.finish().unwrap_err();
        assert!(err.to_string().contains("streaming trace"), "{err}");
        // the error was consumed; a later finish is the idempotent no-op
        sink.finish().unwrap();
    }
}
