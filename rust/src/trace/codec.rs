//! The binary trace format (`.pst`) and the JSON-lines export.
//!
//! Buffered layout, versions 1–2 (all multi-byte integers
//! little-endian; full spec in README.md § Trace format):
//!
//! ```text
//! magic      4 bytes  b"PSTR"
//! version    u16      format version (1 or 2, see below)
//! reserved   u16      0
//! strtab     varint n, then n × (varint len + UTF-8 bytes)
//! meta       name-id, varint seed, f64 horizon, config-id,
//!            varint n_extra, n_extra × (key-id, value-id)
//! events     varint n, then n × record
//! record     varint(bits(t) XOR bits(prev_t))   — delta-encoded time
//!            u8 kind tag
//!            kind-specific fields (varints, string-table ids, f64 bits)
//! ```
//!
//! Streamed layout, version 3+ ([`STREAM_VERSION`], written by
//! `trace::StreamingPstSink` — the memory-flat capture path):
//!
//! ```text
//! magic      4 bytes  b"PSTR"
//! version    u16      3, or 4 when failure records are present
//! reserved   u16      0 at version 3; 1 at version 4+ (streamed flag)
//! events     records back-to-back, identical encoding to v2 — written
//!            as they happen, with no count prefix (unknowable up front)
//! footer     strtab + meta (layouts as above) + varint n_events
//! tail       u64 footer byte offset + 4 bytes b"PSTF"
//! ```
//!
//! A streamed reader seeks the fixed-size tail, parses the footer
//! (string table, meta, event count), then decodes the record body —
//! so the writer holds only the intern table and one record's scratch
//! in memory, never the event stream.
//!
//! Design notes:
//! * **Self-describing**: task/framework/resource names travel through
//!   the interned string table, not enum discriminants — a reader from a
//!   build with different enum ordering still decodes by name, and
//!   unknown names fail loudly instead of silently mislabeling.
//! * **Bit-exact**: timestamps and durations round-trip as raw IEEE-754
//!   bits (times XOR-delta-compressed against the previous event, so
//!   repeated/nearby stamps shrink to a byte or two). Replay digests
//!   depend on this exactness.
//! * **Versioned**: readers accept versions 1 through
//!   [`FORMAT_VERSION`]; any layout change must bump it (versioning
//!   rules in README.md). Version 2 added the preemption records
//!   (`task_preempted` / `task_requeued`); the encoder stamps the
//!   *lowest* version that can represent the trace, so runs without
//!   preemption stay byte-identical to version-1 files and remain
//!   readable by older builds. A version-1 header with a version-2
//!   record is rejected gracefully (a decode error naming the tag,
//!   never a panic or a silent misread). Version 3 marks the streamed
//!   footer-offset layout; only the streaming writer stamps it.
//!   Version 4 added the failure-injection records (`slot_failed` /
//!   `slot_repaired` / `task_checkpointed` / `task_restarted`) and
//!   exists in *both* layouts, disambiguated by the reserved word:
//!   buffered v4 files keep reserved = 0, a streaming writer that had
//!   to admit v4 records patches its header to version 4 with
//!   reserved = 1 at close. Failure-free captures keep stamping v1/v2
//!   (buffered) or v3 (streamed) and stay byte-identical to files from
//!   pre-failure builds.
//!   Version 5 added the placement record (`task_placed`, emitted only
//!   when hardware classes are configured) under the same
//!   lowest-version-that-fits rule: class-free captures keep their old
//!   stamps and stay byte-identical to pre-class builds.
//!   Version 6 added the task-level fault records (`task_failed` /
//!   `task_retried` / `task_timed_out` / `task_shed` /
//!   `pipeline_abandoned`), emitted only when a fault model is
//!   configured. The same rule holds: fault-free captures keep stamping
//!   their old versions and stay byte-identical to pre-fault builds,
//!   and a v6 tag under an older header is rejected by name.

use crate::error::{Error, Result};
use crate::model::{Framework, ResourceKind, TaskType};
use crate::util::binio::{BinRead, ByteReader, ByteWriter, InternTable};
use crate::util::Json;

use super::{Trace, TraceEvent, TraceEventKind, TraceMeta};

/// File magic: **P**ipe**S**im **TR**ace.
pub const MAGIC: &[u8; 4] = b"PSTR";
/// Newest binary format version this build writes and reads. The
/// buffered encoder stamps each file with the lowest version that can
/// represent it (see [`needed_version`]); the decoder accepts
/// `1..=FORMAT_VERSION`, dispatching `STREAM_VERSION` files to the
/// footer-offset reader.
pub const FORMAT_VERSION: u16 = 6;
/// First version of the streamed footer-offset layout (see the module
/// docs). Stamped only by `trace::StreamingPstSink`, which cannot know
/// the event count — or whether preemption/failure records will occur —
/// up front. A version-3 file is always streamed; version 4+ files
/// carry the layout in the header's reserved word (1 = streamed).
pub const STREAM_VERSION: u16 = 3;
/// Reserved-word value marking a version-4+ file as the streamed
/// footer-offset layout rather than the buffered one.
pub const STREAMED_FLAG: u16 = 1;
/// Trailing magic of a streamed file: the last 12 bytes are
/// `u64 footer_offset ++ TAIL_MAGIC`. Its absence means the writer
/// never finalized (crashed mid-run) — rejected loudly.
pub const TAIL_MAGIC: &[u8; 4] = b"PSTF";

// Event kind tags (u8). Append-only: reusing or reordering tags is a
// format break; *appending* tags bumps FORMAT_VERSION and records the
// first version carrying the tag in `tag_min_version`.
const TAG_ARRIVAL_GAP: u8 = 0;
const TAG_PIPELINE_ARRIVAL: u8 = 1;
const TAG_TASK_QUEUED: u8 = 2;
const TAG_TASK_STARTED: u8 = 3;
const TAG_TASK_GRANTED: u8 = 4;
const TAG_TASK_DONE: u8 = 5;
const TAG_MODEL_METRIC: u8 = 6;
const TAG_PIPELINE_DONE: u8 = 7;
const TAG_RETRAIN_TRIGGERED: u8 = 8;
const TAG_RETRAIN_LAUNCHED: u8 = 9;
const TAG_MODEL_DEPLOYED: u8 = 10;
// version 2 (preemptive schedulers)
const TAG_TASK_PREEMPTED: u8 = 11;
const TAG_TASK_REQUEUED: u8 = 12;
// version 4 (failure injection; 3 is the streamed-layout marker, which
// carries no tags of its own)
const TAG_SLOT_FAILED: u8 = 13;
const TAG_SLOT_REPAIRED: u8 = 14;
const TAG_TASK_CHECKPOINTED: u8 = 15;
const TAG_TASK_RESTARTED: u8 = 16;
// version 5 (heterogeneous hardware classes)
const TAG_TASK_PLACED: u8 = 17;
// version 6 (task-level faults)
const TAG_TASK_FAILED: u8 = 18;
const TAG_TASK_RETRIED: u8 = 19;
const TAG_TASK_TIMED_OUT: u8 = 20;
const TAG_TASK_SHED: u8 = 21;
const TAG_PIPELINE_ABANDONED: u8 = 22;

/// First format version that can carry `tag`.
pub(super) fn tag_min_version(tag: u8) -> u16 {
    if tag >= TAG_TASK_FAILED {
        6
    } else if tag >= TAG_TASK_PLACED {
        5
    } else if tag >= TAG_SLOT_FAILED {
        4
    } else if tag >= TAG_TASK_PREEMPTED {
        2
    } else {
        1
    }
}

/// First format version that can carry `kind` — the in-memory twin of
/// [`tag_min_version`], used by the streaming writer to decide at close
/// whether its header must be patched up to version 4.
pub(crate) fn kind_min_version(kind: &TraceEventKind) -> u16 {
    match kind {
        TraceEventKind::TaskFailed { .. }
        | TraceEventKind::TaskRetried { .. }
        | TraceEventKind::TaskTimedOut { .. }
        | TraceEventKind::TaskShed { .. }
        | TraceEventKind::PipelineAbandoned { .. } => 6,
        TraceEventKind::TaskPlaced { .. } => 5,
        TraceEventKind::SlotFailed { .. }
        | TraceEventKind::SlotRepaired { .. }
        | TraceEventKind::TaskCheckpointed { .. }
        | TraceEventKind::TaskRestarted { .. } => 4,
        TraceEventKind::TaskPreempted { .. } | TraceEventKind::TaskRequeued { .. } => 2,
        _ => 1,
    }
}

/// Lowest format version able to represent every event in the trace.
pub fn needed_version(trace: &Trace) -> u16 {
    trace
        .events
        .iter()
        .map(|e| kind_min_version(&e.kind))
        .max()
        .unwrap_or(1)
}

/// Encode the meta block (shared by the buffered encoder and the
/// streaming writer — both intern the meta strings *first*, so the two
/// paths build their string tables in the same order).
pub(crate) fn encode_meta(w: &mut ByteWriter, tab: &mut InternTable, meta: &TraceMeta) {
    w.varint(tab.intern(&meta.name) as u64);
    w.varint(meta.seed);
    w.f64(meta.horizon);
    w.varint(tab.intern(&meta.config_json) as u64);
    w.varint(meta.extra.len() as u64);
    for (k, v) in &meta.extra {
        w.varint(tab.intern(k) as u64);
        w.varint(tab.intern(v) as u64);
    }
}

/// Decode the meta block previously written by [`encode_meta`].
pub(super) fn decode_meta(r: &mut ByteReader, names: &[String]) -> Result<TraceMeta> {
    let name = lookup(names, r.varint()?)?.to_string();
    let seed = r.varint()?;
    let horizon = r.f64()?;
    let config_json = lookup(names, r.varint()?)?.to_string();
    // length prefixes are validated against the remaining input (an
    // extra pair is >= 2 varint bytes), so a corrupt count can never
    // drive an allocation beyond the file size
    let n_extra = r.len_prefix_for(2)?;
    let mut extra = Vec::with_capacity(n_extra);
    for _ in 0..n_extra {
        let k = lookup(names, r.varint()?)?.to_string();
        let v = lookup(names, r.varint()?)?.to_string();
        extra.push((k, v));
    }
    Ok(TraceMeta {
        name,
        seed,
        horizon,
        config_json,
        extra,
    })
}

/// Decode `n_events` XOR-delta event records — the one decode loop the
/// buffered and streamed layouts share (replay digests hang off its
/// exactness, so it exists once).
fn decode_events(
    r: &mut ByteReader,
    names: &[String],
    version: u16,
    n_events: usize,
) -> Result<Vec<TraceEvent>> {
    let mut events = Vec::with_capacity(n_events);
    let mut prev_bits = 0u64;
    for _ in 0..n_events {
        let bits = prev_bits ^ r.varint()?;
        prev_bits = bits;
        let t = f64::from_bits(bits);
        let kind = decode_kind(r, names, version)?;
        events.push(TraceEvent { t, kind });
    }
    Ok(events)
}

/// Serialize a trace to the buffered binary format (v1/v2).
pub fn encode(trace: &Trace) -> Vec<u8> {
    let mut tab = InternTable::new();
    // meta + events intern strings as they serialize; the table is
    // complete once both bodies are encoded, then the file assembles as
    // header + table + bodies.
    let mut meta = ByteWriter::new();
    encode_meta(&mut meta, &mut tab, &trace.meta);

    let mut body = ByteWriter::new();
    body.varint(trace.events.len() as u64);
    let mut prev_bits = 0u64; // bits of t = 0.0
    for ev in &trace.events {
        let bits = ev.t.to_bits();
        body.varint(bits ^ prev_bits);
        prev_bits = bits;
        encode_kind(&mut body, &mut tab, &ev.kind);
    }

    let mut out = ByteWriter::new();
    out.header(MAGIC, needed_version(trace));
    tab.write(&mut out);
    out.bytes(&meta.into_bytes());
    out.bytes(&body.into_bytes());
    out.into_bytes()
}

fn sid(w: &mut ByteWriter, tab: &mut InternTable, s: &str) {
    w.varint(tab.intern(s) as u64);
}

/// `Option<Framework>` as varint: 0 = none, else string id + 1.
fn opt_fw(w: &mut ByteWriter, tab: &mut InternTable, fw: Option<Framework>) {
    match fw {
        None => w.varint(0),
        Some(f) => w.varint(tab.intern(f.name()) as u64 + 1),
    }
}

pub(crate) fn encode_kind(w: &mut ByteWriter, tab: &mut InternTable, kind: &TraceEventKind) {
    match *kind {
        TraceEventKind::ArrivalGapDrawn { gap } => {
            w.u8(TAG_ARRIVAL_GAP);
            w.f64(gap);
        }
        TraceEventKind::PipelineArrival {
            pid,
            framework,
            n_tasks,
            priority,
            retrain_of,
        } => {
            w.u8(TAG_PIPELINE_ARRIVAL);
            w.varint(pid as u64);
            sid(w, tab, framework.name());
            w.u8(n_tasks);
            w.f64(priority);
            w.varint(retrain_of.map_or(0, |s| s as u64 + 1));
        }
        TraceEventKind::TaskQueued {
            pid,
            task,
            resource,
        } => {
            w.u8(TAG_TASK_QUEUED);
            w.varint(pid as u64);
            sid(w, tab, task.name());
            sid(w, tab, resource.name());
        }
        TraceEventKind::TaskStarted {
            pid,
            task,
            framework,
            exec,
            read,
            write,
        } => {
            w.u8(TAG_TASK_STARTED);
            w.varint(pid as u64);
            sid(w, tab, task.name());
            opt_fw(w, tab, framework);
            w.f64(exec);
            w.f64(read);
            w.f64(write);
        }
        TraceEventKind::TaskGranted {
            pid,
            task,
            resource,
            waited,
        } => {
            w.u8(TAG_TASK_GRANTED);
            w.varint(pid as u64);
            sid(w, tab, task.name());
            sid(w, tab, resource.name());
            w.f64(waited);
        }
        TraceEventKind::TaskDone {
            pid,
            task,
            framework,
            exec,
        } => {
            w.u8(TAG_TASK_DONE);
            w.varint(pid as u64);
            sid(w, tab, task.name());
            opt_fw(w, tab, framework);
            w.f64(exec);
        }
        TraceEventKind::TaskPreempted {
            pid,
            task,
            resource,
            by,
            remaining,
        } => {
            w.u8(TAG_TASK_PREEMPTED);
            w.varint(pid as u64);
            sid(w, tab, task.name());
            sid(w, tab, resource.name());
            w.varint(by as u64);
            w.f64(remaining);
        }
        TraceEventKind::TaskRequeued {
            pid,
            task,
            resource,
        } => {
            w.u8(TAG_TASK_REQUEUED);
            w.varint(pid as u64);
            sid(w, tab, task.name());
            sid(w, tab, resource.name());
        }
        TraceEventKind::SlotFailed { resource, offline } => {
            w.u8(TAG_SLOT_FAILED);
            sid(w, tab, resource.name());
            w.varint(offline as u64);
        }
        TraceEventKind::SlotRepaired {
            resource,
            offline,
            downtime,
        } => {
            w.u8(TAG_SLOT_REPAIRED);
            sid(w, tab, resource.name());
            w.varint(offline as u64);
            w.f64(downtime);
        }
        TraceEventKind::TaskCheckpointed {
            pid,
            task,
            preserved,
            lost,
        } => {
            w.u8(TAG_TASK_CHECKPOINTED);
            w.varint(pid as u64);
            sid(w, tab, task.name());
            w.f64(preserved);
            w.f64(lost);
        }
        TraceEventKind::TaskRestarted {
            pid,
            task,
            resource,
            remaining,
        } => {
            w.u8(TAG_TASK_RESTARTED);
            w.varint(pid as u64);
            sid(w, tab, task.name());
            sid(w, tab, resource.name());
            w.f64(remaining);
        }
        TraceEventKind::ModelMetricUpdate {
            pid,
            task,
            performance,
        } => {
            w.u8(TAG_MODEL_METRIC);
            w.varint(pid as u64);
            sid(w, tab, task.name());
            w.f64(performance);
        }
        TraceEventKind::PipelineDone {
            pid,
            makespan,
            total_wait,
            truncated,
        } => {
            w.u8(TAG_PIPELINE_DONE);
            w.varint(pid as u64);
            w.f64(makespan);
            w.f64(total_wait);
            w.u8(truncated as u8);
        }
        TraceEventKind::RetrainTriggered {
            slot,
            drift,
            performance,
            delay,
        } => {
            w.u8(TAG_RETRAIN_TRIGGERED);
            w.varint(slot as u64);
            w.f64(drift);
            w.f64(performance);
            w.f64(delay);
        }
        TraceEventKind::RetrainLaunched { slot } => {
            w.u8(TAG_RETRAIN_LAUNCHED);
            w.varint(slot as u64);
        }
        TraceEventKind::TaskPlaced {
            pid,
            task,
            resource,
            class,
            slots,
        } => {
            w.u8(TAG_TASK_PLACED);
            w.varint(pid as u64);
            sid(w, tab, task.name());
            sid(w, tab, resource.name());
            w.varint(class as u64);
            w.varint(slots as u64);
        }
        TraceEventKind::TaskFailed {
            pid,
            task,
            resource,
            attempt,
            elapsed,
        } => {
            w.u8(TAG_TASK_FAILED);
            w.varint(pid as u64);
            sid(w, tab, task.name());
            sid(w, tab, resource.name());
            w.varint(attempt as u64);
            w.f64(elapsed);
        }
        TraceEventKind::TaskRetried {
            pid,
            task,
            resource,
            attempt,
            delay,
        } => {
            w.u8(TAG_TASK_RETRIED);
            w.varint(pid as u64);
            sid(w, tab, task.name());
            sid(w, tab, resource.name());
            w.varint(attempt as u64);
            w.f64(delay);
        }
        TraceEventKind::TaskTimedOut {
            pid,
            task,
            resource,
            elapsed,
        } => {
            w.u8(TAG_TASK_TIMED_OUT);
            w.varint(pid as u64);
            sid(w, tab, task.name());
            sid(w, tab, resource.name());
            w.f64(elapsed);
        }
        TraceEventKind::TaskShed {
            pid,
            task,
            resource,
            queue_depth,
        } => {
            w.u8(TAG_TASK_SHED);
            w.varint(pid as u64);
            sid(w, tab, task.name());
            sid(w, tab, resource.name());
            w.varint(queue_depth as u64);
        }
        TraceEventKind::PipelineAbandoned {
            pid,
            attempts,
            makespan,
        } => {
            w.u8(TAG_PIPELINE_ABANDONED);
            w.varint(pid as u64);
            w.varint(attempts as u64);
            w.f64(makespan);
        }
        TraceEventKind::ModelDeployed {
            slot,
            performance,
            version,
        } => {
            w.u8(TAG_MODEL_DEPLOYED);
            w.varint(slot as u64);
            w.f64(performance);
            w.varint(version as u64);
        }
    }
}

/// Parse a binary trace. The header is validated through the shared
/// binio container-header helper, accepting versions
/// `1..=FORMAT_VERSION`; anything newer (or not a trace) is an error.
/// Streamed files — version exactly [`STREAM_VERSION`], or newer with
/// the [`STREAMED_FLAG`] reserved word — dispatch to the footer-offset
/// reader; the decoded [`Trace`] is indistinguishable from a buffered
/// capture of the same run.
pub fn decode(bytes: &[u8]) -> Result<Trace> {
    let mut r = ByteReader::new(bytes);
    let (version, reserved) =
        r.check_header_range_with_reserved(MAGIC, 1, FORMAT_VERSION, "trace")?;
    let streamed = version == STREAM_VERSION
        || (version > STREAM_VERSION && reserved == STREAMED_FLAG);
    if streamed {
        return decode_streamed(bytes, version);
    }
    let names = InternTable::read(&mut r)?;
    let meta = decode_meta(&mut r, &names)?;

    // an event record costs >= 3 bytes (time varint + tag + payload)
    let n_events = r.len_prefix_for(3)?;
    let events = decode_events(&mut r, &names, version, n_events)?;
    r.expect_eof("trace")?;
    Ok(Trace { meta, events })
}

/// Parse the streamed footer-offset layout: fixed-size tail → footer
/// (string table, meta, event count) → record body. Truncated files
/// (a writer that died before finalizing) fail on the tail magic.
fn decode_streamed(bytes: &[u8], version: u16) -> Result<Trace> {
    const HEADER: usize = 8; // magic + version + reserved
    const TAIL: usize = 12; // u64 footer offset + tail magic
    if bytes.len() < HEADER + TAIL {
        return Err(Error::Other(format!(
            "trace: streamed file of {} bytes is shorter than header + tail",
            bytes.len()
        )));
    }
    let tail = &bytes[bytes.len() - TAIL..];
    if &tail[8..] != TAIL_MAGIC {
        return Err(Error::Other(
            "trace: streamed file has no footer tail (writer never finalized?)".into(),
        ));
    }
    let mut tr = ByteReader::new(tail);
    let off = usize::try_from(tr.u64()?)
        .map_err(|_| Error::Other("trace: footer offset exceeds usize".into()))?;
    if off < HEADER || off > bytes.len() - TAIL {
        return Err(Error::Other(format!(
            "trace: footer offset {off} outside the file body ({} bytes)",
            bytes.len()
        )));
    }
    // footer: string table + meta + event count
    let mut f = ByteReader::new(&bytes[off..bytes.len() - TAIL]);
    let names = InternTable::read(&mut f)?;
    let meta = decode_meta(&mut f, &names)?;
    let n_events = f.len_prefix()?;
    f.expect_eof("trace footer")?;
    // body: exactly n_events records between header and footer
    let mut b = ByteReader::new(&bytes[HEADER..off]);
    if n_events.saturating_mul(3) > b.remaining() {
        return Err(Error::Other(format!(
            "trace: footer claims {n_events} events, body holds {} bytes",
            b.remaining()
        )));
    }
    let events = decode_events(&mut b, &names, version, n_events)?;
    b.expect_eof("trace events")?;
    Ok(Trace { meta, events })
}

/// Resolve a string-table id, failing loudly on out-of-range ids.
pub(super) fn lookup(names: &[String], id: u64) -> Result<&str> {
    usize::try_from(id)
        .ok()
        .and_then(|i| names.get(i))
        .map(|s| s.as_str())
        .ok_or_else(|| Error::Other(format!("trace: string id {id} out of range")))
}

fn task_by_name(s: &str) -> Result<TaskType> {
    TaskType::ALL
        .iter()
        .find(|t| t.name() == s)
        .copied()
        .ok_or_else(|| Error::Other(format!("trace: unknown task '{s}'")))
}

fn resource_by_name(s: &str) -> Result<ResourceKind> {
    match s {
        "training" => Ok(ResourceKind::Training),
        "compute" => Ok(ResourceKind::Compute),
        other => Err(Error::Other(format!("trace: unknown resource '{other}'"))),
    }
}

fn pid32(v: u64) -> Result<u32> {
    u32::try_from(v).map_err(|_| Error::Other(format!("trace: id {v} exceeds u32")))
}

/// Decode one event-kind record from any [`BinRead`] source — the slice
/// readers of the buffered/streamed loaders and the file-backed
/// iterator of [`scan`](super::scan) share this single implementation.
pub(super) fn decode_kind<R: BinRead>(
    r: &mut R,
    names: &[String],
    version: u16,
) -> Result<TraceEventKind> {
    fn opt_fw<R: BinRead>(r: &mut R, names: &[String]) -> Result<Option<Framework>> {
        match r.varint()? {
            0 => Ok(None),
            id => Framework::parse_name(lookup(names, id - 1)?).map(Some),
        }
    }
    let tag = r.u8()?;
    if tag <= TAG_PIPELINE_ABANDONED && tag_min_version(tag) > version {
        // a tag from a newer layout inside an old-version header: the
        // file is corrupt or mislabeled — refuse rather than misread
        return Err(Error::Other(format!(
            "trace: event tag {tag} requires format version {} but the file header says {version}",
            tag_min_version(tag)
        )));
    }
    Ok(match tag {
        TAG_ARRIVAL_GAP => TraceEventKind::ArrivalGapDrawn { gap: r.f64()? },
        TAG_PIPELINE_ARRIVAL => TraceEventKind::PipelineArrival {
            pid: pid32(r.varint()?)?,
            framework: Framework::parse_name(lookup(names, r.varint()?)?)?,
            n_tasks: r.u8()?,
            priority: r.f64()?,
            retrain_of: match r.varint()? {
                0 => None,
                v => Some(pid32(v - 1)?),
            },
        },
        TAG_TASK_QUEUED => TraceEventKind::TaskQueued {
            pid: pid32(r.varint()?)?,
            task: task_by_name(lookup(names, r.varint()?)?)?,
            resource: resource_by_name(lookup(names, r.varint()?)?)?,
        },
        TAG_TASK_STARTED => TraceEventKind::TaskStarted {
            pid: pid32(r.varint()?)?,
            task: task_by_name(lookup(names, r.varint()?)?)?,
            framework: opt_fw(r, names)?,
            exec: r.f64()?,
            read: r.f64()?,
            write: r.f64()?,
        },
        TAG_TASK_GRANTED => TraceEventKind::TaskGranted {
            pid: pid32(r.varint()?)?,
            task: task_by_name(lookup(names, r.varint()?)?)?,
            resource: resource_by_name(lookup(names, r.varint()?)?)?,
            waited: r.f64()?,
        },
        TAG_TASK_DONE => TraceEventKind::TaskDone {
            pid: pid32(r.varint()?)?,
            task: task_by_name(lookup(names, r.varint()?)?)?,
            framework: opt_fw(r, names)?,
            exec: r.f64()?,
        },
        TAG_TASK_PREEMPTED => TraceEventKind::TaskPreempted {
            pid: pid32(r.varint()?)?,
            task: task_by_name(lookup(names, r.varint()?)?)?,
            resource: resource_by_name(lookup(names, r.varint()?)?)?,
            by: pid32(r.varint()?)?,
            remaining: r.f64()?,
        },
        TAG_TASK_REQUEUED => TraceEventKind::TaskRequeued {
            pid: pid32(r.varint()?)?,
            task: task_by_name(lookup(names, r.varint()?)?)?,
            resource: resource_by_name(lookup(names, r.varint()?)?)?,
        },
        TAG_SLOT_FAILED => TraceEventKind::SlotFailed {
            resource: resource_by_name(lookup(names, r.varint()?)?)?,
            offline: pid32(r.varint()?)?,
        },
        TAG_SLOT_REPAIRED => TraceEventKind::SlotRepaired {
            resource: resource_by_name(lookup(names, r.varint()?)?)?,
            offline: pid32(r.varint()?)?,
            downtime: r.f64()?,
        },
        TAG_TASK_CHECKPOINTED => TraceEventKind::TaskCheckpointed {
            pid: pid32(r.varint()?)?,
            task: task_by_name(lookup(names, r.varint()?)?)?,
            preserved: r.f64()?,
            lost: r.f64()?,
        },
        TAG_TASK_RESTARTED => TraceEventKind::TaskRestarted {
            pid: pid32(r.varint()?)?,
            task: task_by_name(lookup(names, r.varint()?)?)?,
            resource: resource_by_name(lookup(names, r.varint()?)?)?,
            remaining: r.f64()?,
        },
        TAG_TASK_PLACED => TraceEventKind::TaskPlaced {
            pid: pid32(r.varint()?)?,
            task: task_by_name(lookup(names, r.varint()?)?)?,
            resource: resource_by_name(lookup(names, r.varint()?)?)?,
            class: pid32(r.varint()?)?,
            slots: pid32(r.varint()?)?,
        },
        TAG_MODEL_METRIC => TraceEventKind::ModelMetricUpdate {
            pid: pid32(r.varint()?)?,
            task: task_by_name(lookup(names, r.varint()?)?)?,
            performance: r.f64()?,
        },
        TAG_PIPELINE_DONE => TraceEventKind::PipelineDone {
            pid: pid32(r.varint()?)?,
            makespan: r.f64()?,
            total_wait: r.f64()?,
            truncated: r.u8()? != 0,
        },
        TAG_RETRAIN_TRIGGERED => TraceEventKind::RetrainTriggered {
            slot: pid32(r.varint()?)?,
            drift: r.f64()?,
            performance: r.f64()?,
            delay: r.f64()?,
        },
        TAG_RETRAIN_LAUNCHED => TraceEventKind::RetrainLaunched {
            slot: pid32(r.varint()?)?,
        },
        TAG_TASK_FAILED => TraceEventKind::TaskFailed {
            pid: pid32(r.varint()?)?,
            task: task_by_name(lookup(names, r.varint()?)?)?,
            resource: resource_by_name(lookup(names, r.varint()?)?)?,
            attempt: pid32(r.varint()?)?,
            elapsed: r.f64()?,
        },
        TAG_TASK_RETRIED => TraceEventKind::TaskRetried {
            pid: pid32(r.varint()?)?,
            task: task_by_name(lookup(names, r.varint()?)?)?,
            resource: resource_by_name(lookup(names, r.varint()?)?)?,
            attempt: pid32(r.varint()?)?,
            delay: r.f64()?,
        },
        TAG_TASK_TIMED_OUT => TraceEventKind::TaskTimedOut {
            pid: pid32(r.varint()?)?,
            task: task_by_name(lookup(names, r.varint()?)?)?,
            resource: resource_by_name(lookup(names, r.varint()?)?)?,
            elapsed: r.f64()?,
        },
        TAG_TASK_SHED => TraceEventKind::TaskShed {
            pid: pid32(r.varint()?)?,
            task: task_by_name(lookup(names, r.varint()?)?)?,
            resource: resource_by_name(lookup(names, r.varint()?)?)?,
            queue_depth: pid32(r.varint()?)?,
        },
        TAG_PIPELINE_ABANDONED => TraceEventKind::PipelineAbandoned {
            pid: pid32(r.varint()?)?,
            attempts: pid32(r.varint()?)?,
            makespan: r.f64()?,
        },
        TAG_MODEL_DEPLOYED => TraceEventKind::ModelDeployed {
            slot: pid32(r.varint()?)?,
            performance: r.f64()?,
            version: pid32(r.varint()?)?,
        },
        tag => return Err(Error::Other(format!("trace: unknown event tag {tag}"))),
    })
}

/// JSON-lines export: meta on the first line, one event object per line.
pub fn to_jsonl(trace: &Trace) -> String {
    let mut out = String::new();
    out.push_str(&jsonl_meta_line(
        &trace.meta,
        needed_version(trace),
        trace.events.len() as u64,
    ));
    out.push('\n');
    for ev in &trace.events {
        out.push_str(&event_json(ev).to_string());
        out.push('\n');
    }
    out
}

/// The header line of the JSON-lines export, built from the metadata
/// alone — the streamed exporter calls this with the file header's
/// version and record count so it never needs the event `Vec`.
pub fn jsonl_meta_line(meta: &TraceMeta, format_version: u16, events: u64) -> String {
    let config = Json::parse(&meta.config_json).unwrap_or(Json::Null);
    Json::obj(vec![
        ("name", Json::Str(meta.name.clone())),
        // a string: JSON numbers are f64 and would clip seeds above 2^53
        ("seed", Json::Str(meta.seed.to_string())),
        ("horizon", Json::Num(meta.horizon)),
        ("format_version", Json::Num(format_version as f64)),
        ("events", Json::Num(events as f64)),
        (
            "extra",
            Json::Obj(
                meta.extra
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                    .collect(),
            ),
        ),
        ("config", config),
    ])
    .to_string()
}

/// One event's JSON-lines record (no trailing newline).
pub fn jsonl_event_line(ev: &TraceEvent) -> String {
    event_json(ev).to_string()
}

fn event_json(ev: &TraceEvent) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("t", Json::Num(ev.t)),
        ("kind", Json::Str(ev.kind.name().into())),
    ];
    match ev.kind {
        TraceEventKind::ArrivalGapDrawn { gap } => fields.push(("gap", Json::Num(gap))),
        TraceEventKind::PipelineArrival {
            pid,
            framework,
            n_tasks,
            priority,
            retrain_of,
        } => {
            fields.push(("pid", Json::Num(pid as f64)));
            fields.push(("framework", Json::Str(framework.name().into())));
            fields.push(("n_tasks", Json::Num(n_tasks as f64)));
            fields.push(("priority", Json::Num(priority)));
            fields.push((
                "retrain_of",
                retrain_of.map_or(Json::Null, |s| Json::Num(s as f64)),
            ));
        }
        TraceEventKind::TaskQueued {
            pid,
            task,
            resource,
        } => {
            fields.push(("pid", Json::Num(pid as f64)));
            fields.push(("task", Json::Str(task.name().into())));
            fields.push(("resource", Json::Str(resource.name().into())));
        }
        TraceEventKind::TaskStarted {
            pid,
            task,
            framework,
            exec,
            read,
            write,
        } => {
            fields.push(("pid", Json::Num(pid as f64)));
            fields.push(("task", Json::Str(task.name().into())));
            fields.push((
                "framework",
                framework.map_or(Json::Null, |f| Json::Str(f.name().into())),
            ));
            fields.push(("exec", Json::Num(exec)));
            fields.push(("read", Json::Num(read)));
            fields.push(("write", Json::Num(write)));
        }
        TraceEventKind::TaskGranted {
            pid,
            task,
            resource,
            waited,
        } => {
            fields.push(("pid", Json::Num(pid as f64)));
            fields.push(("task", Json::Str(task.name().into())));
            fields.push(("resource", Json::Str(resource.name().into())));
            fields.push(("waited", Json::Num(waited)));
        }
        TraceEventKind::TaskDone {
            pid,
            task,
            framework,
            exec,
        } => {
            fields.push(("pid", Json::Num(pid as f64)));
            fields.push(("task", Json::Str(task.name().into())));
            fields.push((
                "framework",
                framework.map_or(Json::Null, |f| Json::Str(f.name().into())),
            ));
            fields.push(("exec", Json::Num(exec)));
        }
        TraceEventKind::TaskPreempted {
            pid,
            task,
            resource,
            by,
            remaining,
        } => {
            fields.push(("pid", Json::Num(pid as f64)));
            fields.push(("task", Json::Str(task.name().into())));
            fields.push(("resource", Json::Str(resource.name().into())));
            fields.push(("by", Json::Num(by as f64)));
            fields.push(("remaining", Json::Num(remaining)));
        }
        TraceEventKind::TaskRequeued {
            pid,
            task,
            resource,
        } => {
            fields.push(("pid", Json::Num(pid as f64)));
            fields.push(("task", Json::Str(task.name().into())));
            fields.push(("resource", Json::Str(resource.name().into())));
        }
        TraceEventKind::SlotFailed { resource, offline } => {
            fields.push(("resource", Json::Str(resource.name().into())));
            fields.push(("offline", Json::Num(offline as f64)));
        }
        TraceEventKind::SlotRepaired {
            resource,
            offline,
            downtime,
        } => {
            fields.push(("resource", Json::Str(resource.name().into())));
            fields.push(("offline", Json::Num(offline as f64)));
            fields.push(("downtime", Json::Num(downtime)));
        }
        TraceEventKind::TaskCheckpointed {
            pid,
            task,
            preserved,
            lost,
        } => {
            fields.push(("pid", Json::Num(pid as f64)));
            fields.push(("task", Json::Str(task.name().into())));
            fields.push(("preserved", Json::Num(preserved)));
            fields.push(("lost", Json::Num(lost)));
        }
        TraceEventKind::TaskRestarted {
            pid,
            task,
            resource,
            remaining,
        } => {
            fields.push(("pid", Json::Num(pid as f64)));
            fields.push(("task", Json::Str(task.name().into())));
            fields.push(("resource", Json::Str(resource.name().into())));
            fields.push(("remaining", Json::Num(remaining)));
        }
        TraceEventKind::ModelMetricUpdate {
            pid,
            task,
            performance,
        } => {
            fields.push(("pid", Json::Num(pid as f64)));
            fields.push(("task", Json::Str(task.name().into())));
            fields.push(("performance", Json::Num(performance)));
        }
        TraceEventKind::PipelineDone {
            pid,
            makespan,
            total_wait,
            truncated,
        } => {
            fields.push(("pid", Json::Num(pid as f64)));
            fields.push(("makespan", Json::Num(makespan)));
            fields.push(("total_wait", Json::Num(total_wait)));
            fields.push(("truncated", Json::Bool(truncated)));
        }
        TraceEventKind::RetrainTriggered {
            slot,
            drift,
            performance,
            delay,
        } => {
            fields.push(("slot", Json::Num(slot as f64)));
            fields.push(("drift", Json::Num(drift)));
            fields.push(("performance", Json::Num(performance)));
            fields.push(("delay", Json::Num(delay)));
        }
        TraceEventKind::RetrainLaunched { slot } => {
            fields.push(("slot", Json::Num(slot as f64)));
        }
        TraceEventKind::TaskPlaced {
            pid,
            task,
            resource,
            class,
            slots,
        } => {
            fields.push(("pid", Json::Num(pid as f64)));
            fields.push(("task", Json::Str(task.name().into())));
            fields.push(("resource", Json::Str(resource.name().into())));
            fields.push(("class", Json::Num(class as f64)));
            fields.push(("slots", Json::Num(slots as f64)));
        }
        TraceEventKind::TaskFailed {
            pid,
            task,
            resource,
            attempt,
            elapsed,
        } => {
            fields.push(("pid", Json::Num(pid as f64)));
            fields.push(("task", Json::Str(task.name().into())));
            fields.push(("resource", Json::Str(resource.name().into())));
            fields.push(("attempt", Json::Num(attempt as f64)));
            fields.push(("elapsed", Json::Num(elapsed)));
        }
        TraceEventKind::TaskRetried {
            pid,
            task,
            resource,
            attempt,
            delay,
        } => {
            fields.push(("pid", Json::Num(pid as f64)));
            fields.push(("task", Json::Str(task.name().into())));
            fields.push(("resource", Json::Str(resource.name().into())));
            fields.push(("attempt", Json::Num(attempt as f64)));
            fields.push(("delay", Json::Num(delay)));
        }
        TraceEventKind::TaskTimedOut {
            pid,
            task,
            resource,
            elapsed,
        } => {
            fields.push(("pid", Json::Num(pid as f64)));
            fields.push(("task", Json::Str(task.name().into())));
            fields.push(("resource", Json::Str(resource.name().into())));
            fields.push(("elapsed", Json::Num(elapsed)));
        }
        TraceEventKind::TaskShed {
            pid,
            task,
            resource,
            queue_depth,
        } => {
            fields.push(("pid", Json::Num(pid as f64)));
            fields.push(("task", Json::Str(task.name().into())));
            fields.push(("resource", Json::Str(resource.name().into())));
            fields.push(("queue_depth", Json::Num(queue_depth as f64)));
        }
        TraceEventKind::PipelineAbandoned {
            pid,
            attempts,
            makespan,
        } => {
            fields.push(("pid", Json::Num(pid as f64)));
            fields.push(("attempts", Json::Num(attempts as f64)));
            fields.push(("makespan", Json::Num(makespan)));
        }
        TraceEventKind::ModelDeployed {
            slot,
            performance,
            version,
        } => {
            fields.push(("slot", Json::Num(slot as f64)));
            fields.push(("performance", Json::Num(performance)));
            fields.push(("version", Json::Num(version as f64)));
        }
    }
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg64;

    fn meta() -> TraceMeta {
        TraceMeta {
            name: "codec-test".into(),
            seed: 42,
            horizon: 86_400.0,
            config_json: r#"{"name":"codec-test"}"#.into(),
            extra: vec![
                ("scheduler".into(), "fifo".into()),
                ("trigger".into(), "off".into()),
            ],
        }
    }

    /// One event of every kind, with awkward float values.
    fn all_kinds() -> Vec<TraceEvent> {
        let e = |t, kind| TraceEvent { t, kind };
        vec![
            e(0.0, TraceEventKind::ArrivalGapDrawn { gap: 1.0 / 3.0 }),
            e(
                1.0 / 3.0,
                TraceEventKind::PipelineArrival {
                    pid: 0,
                    framework: Framework::TensorFlow,
                    n_tasks: 8,
                    priority: 7.0,
                    retrain_of: None,
                },
            ),
            e(
                1.0 / 3.0,
                TraceEventKind::TaskQueued {
                    pid: 0,
                    task: TaskType::Train,
                    resource: ResourceKind::Training,
                },
            ),
            e(
                0.5,
                TraceEventKind::TaskStarted {
                    pid: 1,
                    task: TaskType::Preprocess,
                    framework: None,
                    exec: 12.25,
                    read: 0.05,
                    write: 0.075,
                },
            ),
            e(
                13.0,
                TraceEventKind::TaskGranted {
                    pid: 0,
                    task: TaskType::Train,
                    resource: ResourceKind::Training,
                    waited: 12.666_666_666_7,
                },
            ),
            e(
                99.0,
                TraceEventKind::TaskDone {
                    pid: 0,
                    task: TaskType::Train,
                    framework: Some(Framework::TensorFlow),
                    exec: 86.0,
                },
            ),
            e(
                99.0,
                TraceEventKind::ModelMetricUpdate {
                    pid: 0,
                    task: TaskType::Train,
                    performance: 0.875,
                },
            ),
            e(
                200.0,
                TraceEventKind::PipelineDone {
                    pid: 0,
                    makespan: 199.666_666_666_7,
                    total_wait: 12.666_666_666_7,
                    truncated: true,
                },
            ),
            e(
                3600.0,
                TraceEventKind::RetrainTriggered {
                    slot: 3,
                    drift: 0.061,
                    performance: 0.79,
                    delay: 1800.0,
                },
            ),
            e(
                4000.0,
                TraceEventKind::TaskPreempted {
                    pid: 7,
                    task: TaskType::Train,
                    resource: ResourceKind::Training,
                    by: 9,
                    remaining: 123.456_789,
                },
            ),
            e(
                4000.0,
                TraceEventKind::TaskRequeued {
                    pid: 7,
                    task: TaskType::Train,
                    resource: ResourceKind::Training,
                },
            ),
            e(
                4500.0,
                TraceEventKind::SlotFailed {
                    resource: ResourceKind::Training,
                    offline: 1,
                },
            ),
            e(
                4500.0,
                TraceEventKind::TaskCheckpointed {
                    pid: 7,
                    task: TaskType::Train,
                    preserved: 300.0,
                    lost: 123.456_789,
                },
            ),
            e(
                4500.0,
                TraceEventKind::TaskRestarted {
                    pid: 7,
                    task: TaskType::Train,
                    resource: ResourceKind::Training,
                    remaining: 223.456_789,
                },
            ),
            e(
                5100.0,
                TraceEventKind::SlotRepaired {
                    resource: ResourceKind::Training,
                    offline: 0,
                    downtime: 600.0,
                },
            ),
            e(
                5000.0,
                TraceEventKind::TaskPlaced {
                    pid: 8,
                    task: TaskType::Train,
                    resource: ResourceKind::Training,
                    class: 1,
                    slots: 2,
                },
            ),
            e(5400.0, TraceEventKind::RetrainLaunched { slot: 3 }),
            e(
                7200.0,
                TraceEventKind::ModelDeployed {
                    slot: 3,
                    performance: 0.91,
                    version: 2,
                },
            ),
            e(
                7200.0,
                TraceEventKind::PipelineArrival {
                    pid: u32::MAX,
                    framework: Framework::Other,
                    n_tasks: 3,
                    priority: 0.0,
                    retrain_of: Some(u32::MAX - 1),
                },
            ),
            e(
                7300.0,
                TraceEventKind::TaskFailed {
                    pid: 11,
                    task: TaskType::Train,
                    resource: ResourceKind::Training,
                    attempt: 2,
                    elapsed: 456.789,
                },
            ),
            e(
                7300.0,
                TraceEventKind::TaskRetried {
                    pid: 11,
                    task: TaskType::Train,
                    resource: ResourceKind::Training,
                    attempt: 2,
                    delay: 120.0,
                },
            ),
            e(
                7400.0,
                TraceEventKind::TaskTimedOut {
                    pid: 12,
                    task: TaskType::Evaluate,
                    resource: ResourceKind::Compute,
                    elapsed: 900.0,
                },
            ),
            e(
                7400.0,
                TraceEventKind::TaskShed {
                    pid: 13,
                    task: TaskType::Preprocess,
                    resource: ResourceKind::Compute,
                    queue_depth: 64,
                },
            ),
            e(
                7500.0,
                TraceEventKind::PipelineAbandoned {
                    pid: 11,
                    attempts: 5,
                    makespan: 3210.987_654,
                },
            ),
        ]
    }

    #[test]
    fn roundtrip_all_event_kinds() {
        let t = Trace {
            meta: meta(),
            events: all_kinds(),
        };
        let bytes = encode(&t);
        let back = decode(&bytes).unwrap();
        assert_eq!(back, t);
        // encoding is deterministic
        assert_eq!(encode(&back), bytes);
    }

    #[test]
    fn roundtrip_empty_trace() {
        let t = Trace {
            meta: TraceMeta {
                name: String::new(),
                seed: 0,
                horizon: 0.0,
                config_json: String::new(),
                extra: Vec::new(),
            },
            events: Vec::new(),
        };
        let back = decode(&encode(&t)).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn roundtrip_string_table_beyond_u16() {
        // >65536 distinct strings must round-trip: ids are u32 varints
        let extra: Vec<(String, String)> = (0..70_000)
            .map(|i| (format!("key-{i}"), format!("value-{i}")))
            .collect();
        let t = Trace {
            meta: TraceMeta {
                extra,
                ..meta()
            },
            events: all_kinds(),
        };
        let bytes = encode(&t);
        let back = decode(&bytes).unwrap();
        assert_eq!(back.meta.extra.len(), 70_000);
        assert_eq!(back, t);
    }

    #[test]
    fn roundtrip_randomized_event_streams() {
        // property test: random event streams (monotone timestamps,
        // random kinds/values) survive write → read bit-identically
        for seed in 0..24u64 {
            let mut rng = Pcg64::new(0xC0DEC + seed);
            let mut t = 0.0f64;
            let events: Vec<TraceEvent> = (0..500)
                .map(|i| {
                    t += rng.uniform() * 100.0;
                    let task = TaskType::ALL[rng.below(6)];
                    let fw = Framework::ALL[rng.below(5)];
                    let kind = match rng.below(23) {
                        0 => TraceEventKind::ArrivalGapDrawn {
                            gap: rng.uniform() * 1e4,
                        },
                        1 => TraceEventKind::PipelineArrival {
                            pid: i,
                            framework: fw,
                            n_tasks: 1 + rng.below(8) as u8,
                            priority: rng.below(11) as f64,
                            retrain_of: (rng.uniform() < 0.2).then_some(rng.below(100) as u32),
                        },
                        2 => TraceEventKind::TaskQueued {
                            pid: i,
                            task,
                            resource: ResourceKind::for_task(task),
                        },
                        3 => TraceEventKind::TaskStarted {
                            pid: i,
                            task,
                            framework: (rng.uniform() < 0.5).then_some(fw),
                            exec: rng.uniform() * 1e3,
                            read: rng.uniform(),
                            write: rng.uniform(),
                        },
                        4 => TraceEventKind::TaskGranted {
                            pid: i,
                            task,
                            resource: ResourceKind::for_task(task),
                            waited: rng.uniform() * 1e3,
                        },
                        5 => TraceEventKind::TaskDone {
                            pid: i,
                            task,
                            framework: (rng.uniform() < 0.5).then_some(fw),
                            exec: rng.uniform() * 1e3,
                        },
                        6 => TraceEventKind::ModelMetricUpdate {
                            pid: i,
                            task,
                            performance: rng.uniform(),
                        },
                        7 => TraceEventKind::PipelineDone {
                            pid: i,
                            makespan: rng.uniform() * 1e5,
                            total_wait: rng.uniform() * 1e4,
                            truncated: rng.uniform() < 0.1,
                        },
                        8 => TraceEventKind::RetrainTriggered {
                            slot: rng.below(64) as u32,
                            drift: rng.uniform(),
                            performance: rng.uniform(),
                            delay: rng.uniform() * 1e4,
                        },
                        9 => TraceEventKind::RetrainLaunched {
                            slot: rng.below(64) as u32,
                        },
                        10 => TraceEventKind::ModelDeployed {
                            slot: rng.below(64) as u32,
                            performance: rng.uniform(),
                            version: 1 + rng.below(9) as u32,
                        },
                        11 => TraceEventKind::TaskPreempted {
                            pid: i,
                            task,
                            resource: ResourceKind::for_task(task),
                            by: rng.below(1000) as u32,
                            remaining: rng.uniform() * 1e3,
                        },
                        12 => TraceEventKind::TaskRequeued {
                            pid: i,
                            task,
                            resource: ResourceKind::for_task(task),
                        },
                        13 => TraceEventKind::SlotFailed {
                            resource: ResourceKind::for_task(task),
                            offline: 1 + rng.below(4) as u32,
                        },
                        14 => TraceEventKind::SlotRepaired {
                            resource: ResourceKind::for_task(task),
                            offline: rng.below(4) as u32,
                            downtime: rng.uniform() * 1e4,
                        },
                        15 => TraceEventKind::TaskCheckpointed {
                            pid: i,
                            task,
                            preserved: rng.uniform() * 1e3,
                            lost: rng.uniform() * 1e3,
                        },
                        16 => TraceEventKind::TaskRestarted {
                            pid: i,
                            task,
                            resource: ResourceKind::for_task(task),
                            remaining: rng.uniform() * 1e3,
                        },
                        17 => TraceEventKind::TaskPlaced {
                            pid: i,
                            task,
                            resource: ResourceKind::for_task(task),
                            class: rng.below(4) as u32,
                            slots: 1 + rng.below(4) as u32,
                        },
                        18 => TraceEventKind::TaskFailed {
                            pid: i,
                            task,
                            resource: ResourceKind::for_task(task),
                            attempt: 1 + rng.below(9) as u32,
                            elapsed: rng.uniform() * 1e3,
                        },
                        19 => TraceEventKind::TaskRetried {
                            pid: i,
                            task,
                            resource: ResourceKind::for_task(task),
                            attempt: 1 + rng.below(9) as u32,
                            delay: rng.uniform() * 1e3,
                        },
                        20 => TraceEventKind::TaskTimedOut {
                            pid: i,
                            task,
                            resource: ResourceKind::for_task(task),
                            elapsed: rng.uniform() * 1e3,
                        },
                        21 => TraceEventKind::TaskShed {
                            pid: i,
                            task,
                            resource: ResourceKind::for_task(task),
                            queue_depth: rng.below(256) as u32,
                        },
                        _ => TraceEventKind::PipelineAbandoned {
                            pid: i,
                            attempts: 1 + rng.below(9) as u32,
                            makespan: rng.uniform() * 1e5,
                        },
                    };
                    TraceEvent { t, kind }
                })
                .collect();
            let trace = Trace {
                meta: meta(),
                events,
            };
            let back = decode(&encode(&trace)).unwrap();
            assert_eq!(back, trace, "seed {seed}");
        }
    }

    #[test]
    fn rejects_bad_magic_version_and_truncation() {
        let t = Trace {
            meta: meta(),
            events: all_kinds(),
        };
        let bytes = encode(&t);
        // magic
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(decode(&bad).is_err());
        // version
        let mut bad = bytes.clone();
        bad[4] = 0xff;
        assert!(decode(&bad).is_err());
        // truncation at every prefix must error, never panic
        for cut in [5, 8, 20, bytes.len() / 2, bytes.len() - 1] {
            assert!(decode(&bytes[..cut]).is_err(), "cut {cut}");
        }
        // trailing garbage
        let mut bad = bytes.clone();
        bad.push(0);
        assert!(decode(&bad).is_err());
    }

    #[test]
    fn version_stamp_is_the_lowest_that_fits() {
        // no preemption records -> version 1 on the wire, readable by
        // pre-preemption builds
        let v1 = Trace {
            meta: meta(),
            events: vec![TraceEvent {
                t: 1.0,
                kind: TraceEventKind::ArrivalGapDrawn { gap: 2.0 },
            }],
        };
        let bytes = encode(&v1);
        assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), 1);
        assert_eq!(decode(&bytes).unwrap(), v1);
        // preemption records (but no failures) -> version 2
        let v2 = Trace {
            meta: meta(),
            events: vec![TraceEvent {
                t: 1.0,
                kind: TraceEventKind::TaskPreempted {
                    pid: 7,
                    task: TaskType::Train,
                    resource: ResourceKind::Training,
                    by: 9,
                    remaining: 5.0,
                },
            }],
        };
        let bytes = encode(&v2);
        assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), 2);
        assert_eq!(decode(&bytes).unwrap(), v2);
        // failure records (but no placement) -> version 4 (3 is
        // streamed-only), buffered layout signalled by reserved = 0
        let v4 = Trace {
            meta: meta(),
            events: vec![TraceEvent {
                t: 1.0,
                kind: TraceEventKind::SlotFailed {
                    resource: ResourceKind::Training,
                    offline: 1,
                },
            }],
        };
        let bytes = encode(&v4);
        assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), 4);
        assert_eq!(u16::from_le_bytes([bytes[6], bytes[7]]), 0);
        assert_eq!(decode(&bytes).unwrap(), v4);
        // placement records (but no fault records) -> version 5
        let v5 = Trace {
            meta: meta(),
            events: vec![TraceEvent {
                t: 1.0,
                kind: TraceEventKind::TaskPlaced {
                    pid: 8,
                    task: TaskType::Train,
                    resource: ResourceKind::Training,
                    class: 1,
                    slots: 2,
                },
            }],
        };
        let bytes = encode(&v5);
        assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), 5);
        assert_eq!(u16::from_le_bytes([bytes[6], bytes[7]]), 0);
        assert_eq!(decode(&bytes).unwrap(), v5);
        // fault records -> version 6; all_kinds has them
        let v6 = Trace {
            meta: meta(),
            events: all_kinds(),
        };
        let bytes = encode(&v6);
        assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), 6);
        assert_eq!(u16::from_le_bytes([bytes[6], bytes[7]]), 0);
        assert_eq!(decode(&bytes).unwrap(), v6);
    }

    #[test]
    fn old_version_header_rejects_preemption_tags_gracefully() {
        // craft a corrupt file: newer records under an older-version
        // header. The decoder must fail with a tagged error, not panic
        // or silently misread.
        let t = Trace {
            meta: meta(),
            events: all_kinds(),
        };
        let mut bytes = encode(&t);
        assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), 6);
        bytes[4] = 1;
        bytes[5] = 0;
        // the preemption record comes first in all_kinds, so the v1
        // relabel trips on the version-2 requirement
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(
            err.contains("requires format version 2"),
            "unexpected error: {err}"
        );
        // a v2 relabel admits the preemption tags but trips on the
        // failure records
        let mut bytes = encode(&t);
        bytes[4] = 2;
        bytes[5] = 0;
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(
            err.contains("requires format version 4"),
            "unexpected error: {err}"
        );
        // a v4 relabel admits the failure tags but trips on the
        // placement record
        let mut bytes = encode(&t);
        bytes[4] = 4;
        bytes[5] = 0;
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(
            err.contains("requires format version 5"),
            "unexpected error: {err}"
        );
        // a v5 relabel admits the placement record but trips on the
        // fault records
        let mut bytes = encode(&t);
        bytes[4] = 5;
        bytes[5] = 0;
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(
            err.contains("requires format version 6"),
            "unexpected error: {err}"
        );
        // and a future version is refused up front
        let mut future = encode(&t);
        future[4] = FORMAT_VERSION as u8 + 1;
        future[5] = 0;
        let err = decode(&future).unwrap_err().to_string();
        assert!(err.contains("this build reads"), "{err}");
        // a v3 stamp routes to the streamed reader, which demands the
        // footer tail — a relabeled buffered file is rejected loudly
        let mut relabeled = encode(&t);
        relabeled[4] = STREAM_VERSION as u8;
        relabeled[5] = 0;
        let err = decode(&relabeled).unwrap_err().to_string();
        assert!(err.contains("footer"), "{err}");
    }

    #[test]
    fn timestamps_compress_but_stay_exact() {
        // many same-time events: the XOR delta is 0 → 1 byte each
        let t0 = 12_345.678_9;
        let events: Vec<TraceEvent> = (0..1000)
            .map(|i| TraceEvent {
                t: t0,
                kind: TraceEventKind::RetrainLaunched { slot: i },
            })
            .collect();
        let trace = Trace {
            meta: meta(),
            events,
        };
        let bytes = encode(&trace);
        let back = decode(&bytes).unwrap();
        assert!(back.events.iter().all(|e| e.t.to_bits() == t0.to_bits()));
        // 1000 events at < ~12 bytes each incl. the slot varint
        assert!(bytes.len() < 600 + 1000 * 12, "{} bytes", bytes.len());
    }

    #[test]
    fn jsonl_export_parses_per_line() {
        let t = Trace {
            meta: meta(),
            events: all_kinds(),
        };
        let jsonl = to_jsonl(&t);
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 1 + t.events.len());
        let head = Json::parse(lines[0]).unwrap();
        assert_eq!(head.s("name").unwrap(), "codec-test");
        // stringly seed: a JSON number is f64 and would clip > 2^53
        assert_eq!(head.s("seed").unwrap(), "42");
        assert_eq!(head.f("events").unwrap(), t.events.len() as f64);
        for (i, line) in lines[1..].iter().enumerate() {
            let j = Json::parse(line).unwrap_or_else(|e| panic!("line {i}: {e}"));
            assert_eq!(j.s("kind").unwrap(), t.events[i].kind.name());
        }
    }
}
