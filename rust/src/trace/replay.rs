//! Replay: turn a captured [`Trace`] back into a runnable workload.
//!
//! A trace carries (a) the full `ExperimentConfig` of the captured run
//! and (b) every interarrival gap the arrival process drew — including
//! the final gap that landed past the horizon. [`TraceWorkload`] feeds
//! those gaps back through the existing [`ArrivalModel::Replay`] path,
//! so the replayed run schedules bit-identical arrival times while every
//! other subsystem (synthesizers, schedulers via `SchedCtx`, triggers,
//! drift) re-runs from the same seed. Given the same fitted
//! [`SimParams`], the replay reproduces the original
//! `ExperimentResult::digest()` byte-for-byte — the round-trip guarantee
//! the trace subsystem is built on (guarded by `rust/tests/trace.rs`).

use std::path::Path;
use std::sync::Arc;

use crate::arrivals::{ArrivalModel, ReplayTrace};
use crate::coordinator::{Experiment, ExperimentConfig, ExperimentResult, SimParams};
use crate::error::{Error, Result};
use crate::runtime::Runtime;

use super::{Trace, TraceEventKind, TraceMeta, TraceScanner};

/// A trace-driven workload: the captured config plus the literal
/// interarrival gap sequence.
#[derive(Clone, Debug)]
pub struct TraceWorkload {
    /// The captured run's full configuration.
    pub config: ExperimentConfig,
    /// Every gap drawn during capture, in draw order (post-scaling).
    pub gaps: Vec<f64>,
}

impl TraceWorkload {
    /// Build a workload from a captured trace. Fails if the trace
    /// carries no config or no arrival gaps (it was not captured by the
    /// simulator, or the file predates gap recording).
    pub fn from_trace(trace: &Trace) -> Result<Self> {
        Self::from_parts(&trace.meta, trace.arrival_gaps())
    }

    /// Build a workload straight off a `.pst` file via [`TraceScanner`],
    /// keeping only the metadata and the interarrival gaps — O(gaps) in
    /// memory instead of O(events). A year-scale capture replays without
    /// ever materializing its event `Vec`; the resulting workload is
    /// identical to `from_trace(&Trace::load(path)?)` (both layouts).
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let scanner = TraceScanner::open(path)?;
        let meta = scanner.meta().clone();
        let mut gaps = Vec::new();
        for ev in scanner {
            if let TraceEventKind::ArrivalGapDrawn { gap } = ev?.kind {
                gaps.push(gap);
            }
        }
        Self::from_parts(&meta, gaps)
    }

    /// Shared tail of both constructors: rebuild the config from the
    /// embedded JSON and validate the gap sequence.
    fn from_parts(meta: &TraceMeta, gaps: Vec<f64>) -> Result<Self> {
        if meta.config_json.is_empty() {
            return Err(Error::Config("replay: trace carries no config".into()));
        }
        let mut config = ExperimentConfig::from_json_text(&meta.config_json)?;
        // the binary meta stores the seed losslessly (varint); the JSON
        // round-trips through f64 and would silently clip seeds above
        // 2^53 — which would shift every RNG substream and break the
        // digest guarantee
        config.seed = meta.seed;
        if gaps.is_empty() {
            return Err(Error::Config(
                "replay: trace has no arrival gaps to drive the simulation".into(),
            ));
        }
        Ok(TraceWorkload { config, gaps })
    }

    /// The replay configuration: identical to the captured one except
    /// (a) `interarrival_factor` is 1 — the recorded gaps are already
    /// post-scaling, so applying the factor twice would distort them —
    /// and (b) `capture_trace` is off, so replaying a large trace does
    /// not silently rebuild a second copy of it in memory. Neither knob
    /// affects the outcome digest.
    ///
    /// Re-enable capture explicitly to re-export. The re-captured trace
    /// has an identical *event stream*; its bytes equal the original
    /// file's only when the captured config already had
    /// `interarrival_factor == 1`, because the embedded config JSON
    /// reflects the rewritten factor otherwise.
    pub fn replay_config(&self) -> ExperimentConfig {
        let mut cfg = self.config.clone();
        cfg.interarrival_factor = 1.0;
        cfg.capture_trace = false;
        cfg
    }

    /// The literal-gap arrival model that overrides the config's arrival
    /// spec during replay.
    pub fn arrival_model(&self) -> ArrivalModel {
        ArrivalModel::Replay(ReplayTrace::new(self.gaps.clone()))
    }

    /// Replay the workload against fitted parameters. Bit-identical to
    /// the captured run's digest when `params` are the same fits the
    /// capture used.
    pub fn run(
        &self,
        params: impl Into<Arc<SimParams>>,
        runtime: Option<Arc<Runtime>>,
    ) -> Result<ExperimentResult> {
        Experiment::new(self.replay_config(), params)
            .with_runtime(runtime)
            .with_arrival(self.arrival_model())
            .run()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{TraceEvent, TraceEventKind, TraceMeta};

    fn trace_with(config_json: &str, gaps: &[f64]) -> Trace {
        Trace {
            meta: TraceMeta {
                name: "t".into(),
                seed: 1,
                horizon: 100.0,
                config_json: config_json.into(),
                extra: Vec::new(),
            },
            events: gaps
                .iter()
                .map(|&gap| TraceEvent {
                    t: 0.0,
                    kind: TraceEventKind::ArrivalGapDrawn { gap },
                })
                .collect(),
        }
    }

    #[test]
    fn workload_extracts_config_and_gaps() {
        let cfg = ExperimentConfig {
            interarrival_factor: 2.0,
            seed: (1 << 60) + 3, // would clip through the f64 JSON path
            ..Default::default()
        };
        let mut trace = trace_with(&cfg.to_json_text(), &[5.0, 7.0, 11.0]);
        trace.meta.seed = cfg.seed;
        let w = TraceWorkload::from_trace(&trace).unwrap();
        assert_eq!(w.gaps, vec![5.0, 7.0, 11.0]);
        assert_eq!(w.config.interarrival_factor, 2.0);
        // the seed comes from the lossless binary meta, not the JSON
        assert_eq!(w.config.seed, (1 << 60) + 3);
        // replay neutralizes the factor (gaps are already scaled) and
        // does not re-capture by default
        assert_eq!(w.replay_config().interarrival_factor, 1.0);
        assert!(!w.replay_config().capture_trace);
        assert!(matches!(w.arrival_model(), ArrivalModel::Replay(_)));
    }

    #[test]
    fn from_file_streams_the_same_workload_as_from_trace() {
        let cfg = ExperimentConfig {
            seed: 42,
            ..Default::default()
        };
        let mut trace = trace_with(&cfg.to_json_text(), &[2.0, 4.0, 8.0]);
        trace.meta.seed = cfg.seed;
        let path = std::env::temp_dir().join(format!(
            "pipesim_replay_from_file_{}.pst",
            std::process::id()
        ));
        trace.save(&path).unwrap();
        let streamed = TraceWorkload::from_file(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let buffered = TraceWorkload::from_trace(&trace).unwrap();
        assert_eq!(streamed.gaps, buffered.gaps);
        assert_eq!(streamed.config.seed, buffered.config.seed);
        assert_eq!(
            streamed.config.to_json_text(),
            buffered.config.to_json_text()
        );
    }

    #[test]
    fn rejects_traces_without_config_or_gaps() {
        let t = trace_with("", &[1.0]);
        assert!(TraceWorkload::from_trace(&t).is_err());
        let t = trace_with(&ExperimentConfig::default().to_json_text(), &[]);
        assert!(TraceWorkload::from_trace(&t).is_err());
    }
}
