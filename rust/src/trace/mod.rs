//! First-class simulation traces (the paper's core loop: "synthetic
//! traces are made available for ad-hoc exploration as well as
//! statistical analysis", section IV-C).
//!
//! A [`Trace`] is the portable event-level record of one simulation run:
//! every pipeline arrival, queue/grant decision, task start/finish,
//! model-metric update, and retraining action, timestamped in simulation
//! time. It closes the platform loop — *simulate → export trace →
//! analyze / re-ingest / replay* — that aggregate results alone cannot:
//!
//! * the simulation core emits into a pluggable [`TraceSink`] behind the
//!   `ExperimentConfig::capture_trace` flag ([`NullSink`] keeps the hot
//!   path allocation-free when capture is off; [`StreamingPstSink`]
//!   writes the binary format incrementally for memory-flat captures);
//! * [`codec`] defines the compact self-describing binary format (magic +
//!   version header, interned string table, delta-encoded timestamps)
//!   plus a JSON-lines export for ad-hoc exploration;
//! * [`replay`] turns a captured trace back into a runnable workload
//!   ([`TraceWorkload`]) whose replay reproduces the original run's
//!   `ExperimentResult::digest()` byte-for-byte (given the same fitted
//!   parameters);
//! * `analytics::trace_stats` summarizes traces and Q-Q-checks them
//!   against the fitted distributions.

pub mod codec;
pub mod replay;
pub mod scan;
pub mod stream;

pub use replay::TraceWorkload;
pub use scan::TraceScanner;
pub use stream::StreamingPstSink;

use crate::des::SimTime;
use crate::model::{Framework, ResourceKind, TaskType};

/// One timestamped simulation event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    /// Simulation time of the event, seconds since experiment start.
    pub t: SimTime,
    pub kind: TraceEventKind,
}

/// The full task-lifecycle event schema. Every variant is `Copy` and
/// string-free, so constructing and emitting an event never allocates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TraceEventKind {
    /// An interarrival gap was drawn from the arrival process — including
    /// the final gap that lands past the horizon and never materializes
    /// as an arrival. The gap sequence is exactly what replay feeds back
    /// through `ArrivalModel::Replay`.
    ArrivalGapDrawn {
        /// Post-scaling gap, seconds (what the calendar actually used).
        gap: f64,
    },
    /// A pipeline entered the system (user arrival or retraining launch).
    PipelineArrival {
        pid: u32,
        framework: Framework,
        /// Tasks in the synthesized pipeline.
        n_tasks: u8,
        /// Priority class (lower = more important; 0 = platform retrain).
        priority: f64,
        /// Deployed-model slot being retrained, if this is a retraining
        /// pipeline.
        retrain_of: Option<u32>,
    },
    /// A task requested its cluster and had to queue.
    TaskQueued {
        pid: u32,
        task: TaskType,
        resource: ResourceKind,
    },
    /// A task started executing — immediately on request, right after a
    /// queue grant (then the paired [`TaskGranted`] precedes it at the
    /// same timestamp), or resuming after a preemption. Every executed
    /// task gets at least one `TaskStarted` (exactly one unless a
    /// preemptive scheduler evicted it mid-service), so service-time
    /// components are always recorded.
    ///
    /// The `exec`/`read`/`write` components always describe the task's
    /// *full original* service, including on a post-preemption resume —
    /// the slot time actually remaining at a resume is carried by the
    /// preceding [`TaskPreempted`]'s `remaining` field, so consumers
    /// reconstructing busy time must subtract it rather than re-count
    /// the full components.
    ///
    /// [`TaskGranted`]: TraceEventKind::TaskGranted
    /// [`TaskPreempted`]: TraceEventKind::TaskPreempted
    TaskStarted {
        pid: u32,
        task: TaskType,
        framework: Option<Framework>,
        /// Sampled execution (compute) duration, seconds.
        exec: f64,
        /// Store read time, seconds.
        read: f64,
        /// Store write time, seconds.
        write: f64,
    },
    /// A queued task was granted a freed slot and started executing.
    TaskGranted {
        pid: u32,
        task: TaskType,
        resource: ResourceKind,
        /// Time spent queued, seconds.
        waited: f64,
    },
    /// A task finished (read + exec + write all complete).
    TaskDone {
        pid: u32,
        task: TaskType,
        framework: Option<Framework>,
        /// The execution (compute) portion of the task, seconds.
        exec: f64,
    },
    /// A running task was evicted by a preemptive scheduler: its
    /// scheduled completion was cancelled and it re-queues with
    /// `remaining` seconds of service. Always followed by the paired
    /// [`TaskRequeued`] at the same timestamp; the task emits another
    /// [`TaskStarted`] when it resumes (so under preemption a task may
    /// carry several `TaskStarted` records but exactly one `TaskDone`).
    ///
    /// [`TaskRequeued`]: TraceEventKind::TaskRequeued
    /// [`TaskStarted`]: TraceEventKind::TaskStarted
    TaskPreempted {
        pid: u32,
        task: TaskType,
        resource: ResourceKind,
        /// Pipeline whose task evicted this one.
        by: u32,
        /// Service seconds outstanding at eviction.
        remaining: f64,
    },
    /// A preempted task re-entered its cluster's wait queue.
    TaskRequeued {
        pid: u32,
        task: TaskType,
        resource: ResourceKind,
    },
    /// A task updated its pipeline's model metrics (train/compress/harden).
    ModelMetricUpdate {
        pid: u32,
        task: TaskType,
        /// Composite performance p(M) after the update.
        performance: f64,
    },
    /// A pipeline left the system.
    PipelineDone {
        pid: u32,
        /// Arrival-to-completion time, seconds.
        makespan: f64,
        /// Total queueing wait accumulated across all tasks, seconds.
        total_wait: f64,
        /// Whether the quality gate aborted the pipeline.
        truncated: bool,
    },
    /// The retraining trigger strategy fired for a monitored model.
    RetrainTriggered {
        /// Deployed-model slot.
        slot: u32,
        /// Detector drift metric at the decision.
        drift: f64,
        /// Model performance at the decision.
        performance: f64,
        /// Launch delay chosen by the trigger, seconds.
        delay: f64,
    },
    /// A deferred retraining actually launched its pipeline.
    RetrainLaunched {
        /// Deployed-model slot.
        slot: u32,
    },
    /// A cluster slot failed (failure injection). If the slot carried a
    /// running task, the paired [`TaskCheckpointed`] and [`TaskRestarted`]
    /// records follow at the same timestamp.
    ///
    /// [`TaskCheckpointed`]: TraceEventKind::TaskCheckpointed
    /// [`TaskRestarted`]: TraceEventKind::TaskRestarted
    SlotFailed {
        resource: ResourceKind,
        /// Slots offline on the cluster *after* this failure.
        offline: u32,
    },
    /// A failed slot came back online after repair.
    SlotRepaired {
        resource: ResourceKind,
        /// Slots still offline *after* this repair.
        offline: u32,
        /// How long the slot was down, seconds (the MTTR draw).
        downtime: f64,
    },
    /// A failure interrupted a running task: the checkpoint/restart cost
    /// model settled how much of the attempt survives. `preserved` is
    /// the service recovered from the last checkpoint, `lost` the tail
    /// thrown away plus the fixed restart cost — the task re-queues with
    /// `remaining + lost` service outstanding.
    TaskCheckpointed {
        pid: u32,
        task: TaskType,
        /// Attempt progress preserved by checkpointing, seconds.
        preserved: f64,
        /// Service lost: the tail since the last checkpoint plus the
        /// restart cost, seconds.
        lost: f64,
    },
    /// A failure-interrupted task re-entered its cluster's wait queue.
    TaskRestarted {
        pid: u32,
        task: TaskType,
        resource: ResourceKind,
        /// Service outstanding at re-queue (work left + lost tail +
        /// restart cost), seconds.
        remaining: f64,
    },
    /// A granted task was placed onto a hardware class (one record per
    /// allocated class — a gang job spread across classes emits
    /// several at the same timestamp). Emitted only when the cluster is
    /// configured with `hw_classes`, immediately after the grant's
    /// [`TaskStarted`]. Requires trace format v5.
    ///
    /// [`TaskStarted`]: TraceEventKind::TaskStarted
    TaskPlaced {
        pid: u32,
        task: TaskType,
        resource: ResourceKind,
        /// Index of the class in the cluster's ordered class list (the
        /// config JSON embedded in the trace meta names it).
        class: u32,
        /// Slots taken from that class.
        slots: u32,
    },
    /// A running attempt was hit by a transient task fault (the
    /// task-level fault model, distinct from slot-level [`SlotFailed`]):
    /// the attempt's progress is wasted and the retry policy decides
    /// between the paired [`TaskRetried`] and [`PipelineAbandoned`] at
    /// the same timestamp. Requires trace format v6.
    ///
    /// [`SlotFailed`]: TraceEventKind::SlotFailed
    /// [`TaskRetried`]: TraceEventKind::TaskRetried
    /// [`PipelineAbandoned`]: TraceEventKind::PipelineAbandoned
    TaskFailed {
        pid: u32,
        task: TaskType,
        resource: ResourceKind,
        /// 1-based attempt number that faulted.
        attempt: u32,
        /// Attempt progress wasted by the fault, seconds.
        elapsed: f64,
    },
    /// The retry policy answered a fault/timeout with `Retry`: the task
    /// re-enters its cluster after `delay` seconds of backoff. Requires
    /// trace format v6.
    TaskRetried {
        pid: u32,
        task: TaskType,
        resource: ResourceKind,
        /// 1-based attempt number that just failed (the retry runs as
        /// attempt `attempt + 1`).
        attempt: u32,
        /// Backoff delay before the task re-requests its cluster, seconds.
        delay: f64,
    },
    /// A running attempt exceeded the cluster's per-attempt `timeout`
    /// and was killed; the retry policy decides what happens next, as
    /// with [`TaskFailed`]. Requires trace format v6.
    ///
    /// [`TaskFailed`]: TraceEventKind::TaskFailed
    TaskTimedOut {
        pid: u32,
        task: TaskType,
        resource: ResourceKind,
        /// Attempt progress wasted by the timeout (= the timeout),
        /// seconds.
        elapsed: f64,
    },
    /// A fresh pipeline was refused admission because its first task's
    /// cluster queue was at `queue_cap` — a terminal outcome counted in
    /// `ExperimentResult::shed`. Requires trace format v6.
    TaskShed {
        pid: u32,
        task: TaskType,
        resource: ResourceKind,
        /// Jobs waiting on the cluster at the admission decision.
        queue_depth: u32,
    },
    /// The retry policy gave up on a pipeline — a terminal outcome
    /// counted in `ExperimentResult::abandoned`. Requires trace
    /// format v6.
    PipelineAbandoned {
        pid: u32,
        /// Attempts the failing task burned before the policy gave up.
        attempts: u32,
        /// Arrival-to-abandonment time, seconds.
        makespan: f64,
    },
    /// A model (re)deployed into a monitored runtime-view slot. Only
    /// *tracked* deployments get this event: deploys past
    /// `runtime_view.max_models` still count toward the result's
    /// `models_deployed` but are never monitored, so they appear in the
    /// trace only as their `TaskDone { task: deploy }` record.
    ModelDeployed {
        slot: u32,
        performance: f64,
        /// Version in the retraining lineage (1 = first deployment).
        version: u32,
    },
}

impl TraceEventKind {
    /// Stable lowercase name of the event kind (JSON-lines `kind` field).
    pub fn name(&self) -> &'static str {
        match self {
            TraceEventKind::ArrivalGapDrawn { .. } => "arrival_gap",
            TraceEventKind::PipelineArrival { .. } => "pipeline_arrival",
            TraceEventKind::TaskQueued { .. } => "task_queued",
            TraceEventKind::TaskStarted { .. } => "task_started",
            TraceEventKind::TaskGranted { .. } => "task_granted",
            TraceEventKind::TaskDone { .. } => "task_done",
            TraceEventKind::TaskPreempted { .. } => "task_preempted",
            TraceEventKind::TaskRequeued { .. } => "task_requeued",
            TraceEventKind::ModelMetricUpdate { .. } => "model_metric",
            TraceEventKind::PipelineDone { .. } => "pipeline_done",
            TraceEventKind::RetrainTriggered { .. } => "retrain_triggered",
            TraceEventKind::RetrainLaunched { .. } => "retrain_launched",
            TraceEventKind::SlotFailed { .. } => "slot_failed",
            TraceEventKind::SlotRepaired { .. } => "slot_repaired",
            TraceEventKind::TaskCheckpointed { .. } => "task_checkpointed",
            TraceEventKind::TaskRestarted { .. } => "task_restarted",
            TraceEventKind::TaskPlaced { .. } => "task_placed",
            TraceEventKind::TaskFailed { .. } => "task_failed",
            TraceEventKind::TaskRetried { .. } => "task_retried",
            TraceEventKind::TaskTimedOut { .. } => "task_timed_out",
            TraceEventKind::TaskShed { .. } => "task_shed",
            TraceEventKind::PipelineAbandoned { .. } => "pipeline_abandoned",
            TraceEventKind::ModelDeployed { .. } => "model_deployed",
        }
    }
}

/// Where the simulation core sends events when capture is enabled.
///
/// Implementations must not assume anything about event volume: a
/// year-scale run emits hundreds of millions of events. The built-in
/// sinks are [`NullSink`] (the placeholder when capture is off — every
/// emission site is additionally gated on the capture flag, so it
/// receives no traffic in practice), [`MemorySink`] (collect in memory
/// for export), and [`StreamingPstSink`] (write the binary format
/// incrementally — memory-flat captures; inject via
/// `Experiment::with_sink` or `sweep --trace-dir`). Streaming sinks
/// return an empty vec from [`TraceSink::drain`] and finalize their
/// output in [`TraceSink::finish`].
pub trait TraceSink: Send {
    /// Observe one event. Called on the simulation hot path — must not
    /// panic and should not allocate per call.
    fn record(&mut self, ev: &TraceEvent);

    /// Hand the captured events back at end of run. Sinks that stream
    /// elsewhere return an empty vec (the default).
    fn drain(&mut self) -> Vec<TraceEvent> {
        Vec::new()
    }

    /// Called exactly once by the simulation after the final event,
    /// before the result is assembled. Streaming sinks finalize here
    /// (write footers, flush, surface latched IO errors); the default
    /// is a no-op.
    fn finish(&mut self) -> crate::Result<()> {
        Ok(())
    }
}

/// The default sink: drops every event, allocation-free (bench-guarded
/// in `benches/bench_trace.rs`).
#[derive(Clone, Copy, Debug, Default)]
pub struct NullSink;

impl TraceSink for NullSink {
    #[inline]
    fn record(&mut self, _ev: &TraceEvent) {}
}

/// Collects events in memory; the experiment runner drains it into the
/// result's [`Trace`].
#[derive(Debug, Default)]
pub struct MemorySink {
    events: Vec<TraceEvent>,
}

impl MemorySink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

impl TraceSink for MemorySink {
    #[inline]
    fn record(&mut self, ev: &TraceEvent) {
        self.events.push(*ev);
    }

    fn drain(&mut self) -> Vec<TraceEvent> {
        std::mem::take(&mut self.events)
    }
}

/// Run-identifying metadata carried inside a trace file. Everything here
/// is deterministic — two captures of the same `(config, seed)` produce
/// byte-identical trace files.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceMeta {
    /// Experiment name (the config's).
    pub name: String,
    pub seed: u64,
    /// Configured horizon, seconds.
    pub horizon: f64,
    /// Canonical JSON of the full `ExperimentConfig` — replay rebuilds
    /// the exact run definition from this.
    pub config_json: String,
    /// Free-form key/value annotations (strategy labels, provenance).
    pub extra: Vec<(String, String)>,
}

impl TraceMeta {
    /// Look up an annotation by key.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.extra
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
}

/// A captured simulation trace: metadata + the ordered event stream.
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    pub meta: TraceMeta,
    /// Events in emission order (timestamps are non-decreasing).
    pub events: Vec<TraceEvent>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The interarrival gaps drawn during capture, in draw order — the
    /// replay workload's arrival sequence.
    pub fn arrival_gaps(&self) -> Vec<f64> {
        self.events
            .iter()
            .filter_map(|e| match e.kind {
                TraceEventKind::ArrivalGapDrawn { gap } => Some(gap),
                _ => None,
            })
            .collect()
    }

    /// Time span `[first, last]` covered by the events (0,0 when empty).
    pub fn span(&self) -> (SimTime, SimTime) {
        match (self.events.first(), self.events.last()) {
            (Some(a), Some(b)) => (a.t, b.t),
            _ => (0.0, 0.0),
        }
    }

    /// Serialize to the binary trace format (see `codec`).
    pub fn to_bytes(&self) -> Vec<u8> {
        codec::encode(self)
    }

    /// Parse a binary trace previously produced by [`Trace::to_bytes`].
    pub fn from_bytes(bytes: &[u8]) -> crate::Result<Trace> {
        codec::decode(bytes)
    }

    /// Write the binary format to `path`.
    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        std::fs::write(path, self.to_bytes()).map_err(|e| {
            crate::Error::Other(format!("writing trace {}: {e}", path.display()))
        })?;
        Ok(())
    }

    /// Load a binary trace file.
    pub fn load(path: &std::path::Path) -> crate::Result<Trace> {
        let bytes = std::fs::read(path).map_err(|e| {
            crate::Error::Other(format!("reading trace {}: {e}", path.display()))
        })?;
        Self::from_bytes(&bytes)
    }

    /// JSON-lines export for ad-hoc exploration: the first line is the
    /// meta object, then one compact JSON object per event.
    pub fn to_jsonl(&self) -> String {
        codec::to_jsonl(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: f64, kind: TraceEventKind) -> TraceEvent {
        TraceEvent { t, kind }
    }

    #[test]
    fn null_sink_drains_nothing() {
        let mut s = NullSink;
        s.record(&ev(1.0, TraceEventKind::ArrivalGapDrawn { gap: 5.0 }));
        assert!(s.drain().is_empty());
    }

    #[test]
    fn memory_sink_collects_in_order() {
        let mut s = MemorySink::new();
        for i in 0..5 {
            s.record(&ev(i as f64, TraceEventKind::RetrainLaunched { slot: i }));
        }
        assert_eq!(s.len(), 5);
        let events = s.drain();
        assert_eq!(events.len(), 5);
        assert!(s.is_empty());
        assert_eq!(events[3].t, 3.0);
    }

    #[test]
    fn arrival_gaps_and_span_extracted() {
        let t = Trace {
            meta: TraceMeta {
                name: "t".into(),
                seed: 1,
                horizon: 100.0,
                config_json: "{}".into(),
                extra: vec![("scheduler".into(), "fifo".into())],
            },
            events: vec![
                ev(0.0, TraceEventKind::ArrivalGapDrawn { gap: 3.5 }),
                ev(
                    3.5,
                    TraceEventKind::PipelineArrival {
                        pid: 0,
                        framework: Framework::SparkML,
                        n_tasks: 3,
                        priority: 4.0,
                        retrain_of: None,
                    },
                ),
                ev(3.5, TraceEventKind::ArrivalGapDrawn { gap: 9.25 }),
            ],
        };
        assert_eq!(t.arrival_gaps(), vec![3.5, 9.25]);
        assert_eq!(t.span(), (0.0, 3.5));
        assert_eq!(t.meta.get("scheduler"), Some("fifo"));
        assert_eq!(t.meta.get("nope"), None);
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn kind_names_are_stable() {
        assert_eq!(
            TraceEventKind::ArrivalGapDrawn { gap: 0.0 }.name(),
            "arrival_gap"
        );
        assert_eq!(
            TraceEventKind::PipelineDone {
                pid: 0,
                makespan: 0.0,
                total_wait: 0.0,
                truncated: false
            }
            .name(),
            "pipeline_done"
        );
        assert_eq!(
            TraceEventKind::SlotFailed {
                resource: ResourceKind::Training,
                offline: 1
            }
            .name(),
            "slot_failed"
        );
        assert_eq!(
            TraceEventKind::SlotRepaired {
                resource: ResourceKind::Compute,
                offline: 0,
                downtime: 60.0
            }
            .name(),
            "slot_repaired"
        );
        assert_eq!(
            TraceEventKind::TaskCheckpointed {
                pid: 0,
                task: TaskType::Train,
                preserved: 10.0,
                lost: 5.0
            }
            .name(),
            "task_checkpointed"
        );
        assert_eq!(
            TraceEventKind::TaskRestarted {
                pid: 0,
                task: TaskType::Train,
                resource: ResourceKind::Training,
                remaining: 30.0
            }
            .name(),
            "task_restarted"
        );
        assert_eq!(
            TraceEventKind::TaskPlaced {
                pid: 0,
                task: TaskType::Train,
                resource: ResourceKind::Training,
                class: 1,
                slots: 2
            }
            .name(),
            "task_placed"
        );
        assert_eq!(
            TraceEventKind::TaskFailed {
                pid: 0,
                task: TaskType::Train,
                resource: ResourceKind::Training,
                attempt: 1,
                elapsed: 12.5
            }
            .name(),
            "task_failed"
        );
        assert_eq!(
            TraceEventKind::TaskRetried {
                pid: 0,
                task: TaskType::Train,
                resource: ResourceKind::Training,
                attempt: 1,
                delay: 60.0
            }
            .name(),
            "task_retried"
        );
        assert_eq!(
            TraceEventKind::TaskTimedOut {
                pid: 0,
                task: TaskType::Evaluate,
                resource: ResourceKind::Compute,
                elapsed: 900.0
            }
            .name(),
            "task_timed_out"
        );
        assert_eq!(
            TraceEventKind::TaskShed {
                pid: 0,
                task: TaskType::Preprocess,
                resource: ResourceKind::Compute,
                queue_depth: 64
            }
            .name(),
            "task_shed"
        );
        assert_eq!(
            TraceEventKind::PipelineAbandoned {
                pid: 0,
                attempts: 3,
                makespan: 5000.0
            }
            .name(),
            "pipeline_abandoned"
        );
    }
}
