//! Assets (paper section IV-A1c): data assets D = (rows, cols, bytes) and
//! trained models M with static and dynamic metric sets.

use super::task::{Framework, ModelType, PredictionType};

/// A data asset: an observation of the multivariate variable
/// D = (D_d dimensions, D_r rows, D_b bytes), paper section IV-B2.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DataAsset {
    pub rows: f64,
    pub cols: f64,
    pub bytes: f64,
}

impl DataAsset {
    pub fn new(rows: f64, cols: f64, bytes: f64) -> Self {
        DataAsset { rows, cols, bytes }
    }

    /// Dataset dimension rows × cols (the x-axis of Fig 8 right / Fig 9a).
    pub fn size(&self) -> f64 {
        self.rows * self.cols
    }

    /// ln(rows × cols), the input of the preprocess duration curve.
    pub fn log_size(&self) -> f64 {
        self.size().max(1.0).ln()
    }

    /// The paper filters assets with < 50 rows or < 2 columns as unlikely
    /// to train models (section V-A1).
    pub fn is_plausible(&self) -> bool {
        self.rows >= 50.0 && self.cols >= 2.0 && self.bytes > 0.0
    }
}

/// Static + dynamic metrics of a trained model (section III-A).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelMetrics {
    /// Composite model performance p(M) in [0,1] (e.g. accuracy / AUC).
    pub performance: f64,
    /// CLEVER robustness score (static).
    pub clever_score: f64,
    /// Model size in MB (static).
    pub size_mb: f64,
    /// Inference latency in ms (dynamic).
    pub inference_ms: f64,
    /// Scoring confidence (dynamic).
    pub confidence: f64,
    /// Drift metric accumulated at run time (dynamic).
    pub drift: f64,
}

impl Default for ModelMetrics {
    fn default() -> Self {
        ModelMetrics {
            performance: 0.0,
            clever_score: 0.0,
            size_mb: 0.0,
            inference_ms: 0.0,
            confidence: 0.0,
            drift: 0.0,
        }
    }
}

/// A trained ML model asset M produced by a pipeline execution.
#[derive(Clone, Debug)]
pub struct TrainedModel {
    pub id: u64,
    /// Pipeline that produced this model version.
    pub pipeline_id: u64,
    /// Version counter within the pipeline's lineage.
    pub version: u32,
    pub framework: Framework,
    pub prediction_type: PredictionType,
    pub model_type: ModelType,
    pub metrics: ModelMetrics,
    /// Simulation time the model was created.
    pub created_at: f64,
}

impl TrainedModel {
    /// Staleness proxy: performance lost since deployment, section III-A.
    pub fn staleness(&self, initial_performance: f64) -> f64 {
        (initial_performance - self.metrics.performance).max(0.0)
    }

    /// Potential improvement of retraining: staleness weighted with newly
    /// available data (normalized), the quantity the paper proposes
    /// schedulers optimize (section III-A/B).
    pub fn potential_improvement(&self, initial_performance: f64, new_data_fraction: f64) -> f64 {
        let staleness = self.staleness(initial_performance);
        let headroom = 1.0 - self.metrics.performance.clamp(0.0, 1.0);
        (0.5 * staleness + 0.5 * headroom * new_data_fraction.clamp(0.0, 1.0)).clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn asset_size_and_log() {
        let a = DataAsset::new(1000.0, 10.0, 80_000.0);
        assert_eq!(a.size(), 10_000.0);
        assert!((a.log_size() - 10_000f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn plausibility_filter_matches_paper() {
        assert!(DataAsset::new(50.0, 2.0, 1.0).is_plausible());
        assert!(!DataAsset::new(49.0, 10.0, 1.0).is_plausible());
        assert!(!DataAsset::new(100.0, 1.0, 1.0).is_plausible());
        assert!(!DataAsset::new(100.0, 5.0, 0.0).is_plausible());
    }

    fn mk_model(perf: f64) -> TrainedModel {
        TrainedModel {
            id: 1,
            pipeline_id: 1,
            version: 1,
            framework: Framework::TensorFlow,
            prediction_type: PredictionType::Binary,
            model_type: ModelType::NeuralNetwork,
            metrics: ModelMetrics {
                performance: perf,
                ..Default::default()
            },
            created_at: 0.0,
        }
    }

    #[test]
    fn staleness_nonnegative() {
        let m = mk_model(0.8);
        assert!((m.staleness(0.9) - 0.1).abs() < 1e-12);
        assert_eq!(m.staleness(0.7), 0.0); // improved models aren't stale
    }

    #[test]
    fn potential_improvement_bounds() {
        let m = mk_model(0.5);
        let p = m.potential_improvement(0.9, 1.0);
        assert!(p > 0.0 && p <= 1.0);
        // fresher model with no new data -> lower potential
        let fresh = mk_model(0.9);
        assert!(fresh.potential_improvement(0.9, 0.0) < p);
    }
}
