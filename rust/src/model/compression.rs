//! Model-compression effect model, calibrated on the paper's Table I
//! (GoogleNet / ResNet50 on Food101, Caffe, prune levels 0–80%).
//!
//! The paper observes that "the relative changes in model metrics could
//! be described by a regression model" (section V-A2d); this module *is*
//! that regression: quadratic fits of the relative accuracy / size /
//! inference-time change as a function of the prune fraction, calibrated
//! to reproduce Table I.

use super::asset::ModelMetrics;

/// One calibration row of Table I.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Table1Row {
    pub prune_pct: f64,
    pub gn_accuracy: f64,
    pub rn50_accuracy: f64,
    pub gn_size_mb: f64,
    pub rn50_size_mb: f64,
    pub gn_inference_ms: f64,
    pub rn50_inference_ms: f64,
}

/// The verbatim Table I data.
pub const TABLE1: [Table1Row; 5] = [
    Table1Row { prune_pct: 0.0,  gn_accuracy: 80.7, rn50_accuracy: 81.3, gn_size_mb: 42.5, rn50_size_mb: 91.1, gn_inference_ms: 128.0, rn50_inference_ms: 223.0 },
    Table1Row { prune_pct: 20.0, gn_accuracy: 80.9, rn50_accuracy: 80.9, gn_size_mb: 28.7, rn50_size_mb: 83.5, gn_inference_ms: 117.0, rn50_inference_ms: 200.0 },
    Table1Row { prune_pct: 40.0, gn_accuracy: 80.0, rn50_accuracy: 80.8, gn_size_mb: 20.9, rn50_size_mb: 65.2, gn_inference_ms: 100.0, rn50_inference_ms: 169.0 },
    Table1Row { prune_pct: 60.0, gn_accuracy: 77.7, rn50_accuracy: 79.5, gn_size_mb: 14.6, rn50_size_mb: 41.9, gn_inference_ms: 84.0,  rn50_inference_ms: 141.0 },
    Table1Row { prune_pct: 80.0, gn_accuracy: 69.8, rn50_accuracy: 69.8, gn_size_mb: 8.5,  rn50_size_mb: 8.5,  gn_inference_ms: 71.0,  rn50_inference_ms: 72.0 },
];

/// Quadratic y = c0 + c1 x + c2 x^2 fitted by least squares.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Quad {
    pub c0: f64,
    pub c1: f64,
    pub c2: f64,
}

impl Quad {
    pub fn eval(&self, x: f64) -> f64 {
        self.c0 + self.c1 * x + self.c2 * x * x
    }

    /// Least-squares fit through (x, y) pairs (normal equations, 3x3).
    pub fn fit(xs: &[f64], ys: &[f64]) -> Quad {
        assert!(xs.len() == ys.len() && xs.len() >= 3);
        // accumulate moments
        let n = xs.len() as f64;
        let (mut sx, mut sx2, mut sx3, mut sx4) = (0.0, 0.0, 0.0, 0.0);
        let (mut sy, mut sxy, mut sx2y) = (0.0, 0.0, 0.0);
        for (&x, &y) in xs.iter().zip(ys) {
            let x2 = x * x;
            sx += x;
            sx2 += x2;
            sx3 += x2 * x;
            sx4 += x2 * x2;
            sy += y;
            sxy += x * y;
            sx2y += x2 * y;
        }
        // solve [n sx sx2; sx sx2 sx3; sx2 sx3 sx4] c = [sy sxy sx2y]
        let a = [[n, sx, sx2], [sx, sx2, sx3], [sx2, sx3, sx4]];
        let b = [sy, sxy, sx2y];
        let c = solve3(a, b);
        Quad { c0: c[0], c1: c[1], c2: c[2] }
    }
}

/// Solve a 3x3 linear system by Gaussian elimination with partial pivoting.
fn solve3(mut a: [[f64; 3]; 3], mut b: [f64; 3]) -> [f64; 3] {
    for col in 0..3 {
        // pivot
        let piv = (col..3)
            .max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())
            .unwrap();
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        assert!(d.abs() > 1e-12, "singular system");
        for row in (col + 1)..3 {
            let f = a[row][col] / d;
            for k in col..3 {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = [0.0; 3];
    for row in (0..3).rev() {
        let mut s = b[row];
        for k in (row + 1)..3 {
            s -= a[row][k] * x[k];
        }
        x[row] = s / a[row][row];
    }
    x
}

/// Per-network regression of relative metric change under pruning.
#[derive(Clone, Copy, Debug)]
pub struct NetworkQuads {
    /// accuracy(prune)/accuracy(0)
    pub accuracy_ratio: Quad,
    /// size(prune)/size(0)
    pub size_ratio: Quad,
    /// inference(prune)/inference(0)
    pub inference_ratio: Quad,
}

/// Regression model of relative metric change under pruning, calibrated
/// per network (GoogleNet / ResNet50 behave very differently at 80%
/// pruning — Table I's last row).
#[derive(Clone, Debug)]
pub struct CompressionModel {
    pub googlenet: NetworkQuads,
    pub resnet50: NetworkQuads,
}

impl Default for CompressionModel {
    fn default() -> Self {
        Self::from_table1()
    }
}

fn fit_network(rows: impl Iterator<Item = (f64, f64, f64, f64)>) -> NetworkQuads {
    let mut xs = Vec::new();
    let (mut acc, mut size, mut inf) = (Vec::new(), Vec::new(), Vec::new());
    for (p, a, s, i) in rows {
        xs.push(p);
        acc.push(a);
        size.push(s);
        inf.push(i);
    }
    NetworkQuads {
        accuracy_ratio: Quad::fit(&xs, &acc),
        size_ratio: Quad::fit(&xs, &size),
        inference_ratio: Quad::fit(&xs, &inf),
    }
}

impl CompressionModel {
    /// Calibrate the quadratics on Table I's relative changes.
    pub fn from_table1() -> Self {
        let base = &TABLE1[0];
        let googlenet = fit_network(TABLE1.iter().map(|r| {
            (
                r.prune_pct / 100.0,
                r.gn_accuracy / base.gn_accuracy,
                r.gn_size_mb / base.gn_size_mb,
                r.gn_inference_ms / base.gn_inference_ms,
            )
        }));
        let resnet50 = fit_network(TABLE1.iter().map(|r| {
            (
                r.prune_pct / 100.0,
                r.rn50_accuracy / base.rn50_accuracy,
                r.rn50_size_mb / base.rn50_size_mb,
                r.rn50_inference_ms / base.rn50_inference_ms,
            )
        }));
        CompressionModel {
            googlenet,
            resnet50,
        }
    }

    /// Generic ratio (mean of both calibrated networks) — what the
    /// simulator applies to an arbitrary model.
    fn ratios(&self, p: f64) -> (f64, f64, f64) {
        (
            0.5 * (self.googlenet.accuracy_ratio.eval(p) + self.resnet50.accuracy_ratio.eval(p)),
            0.5 * (self.googlenet.size_ratio.eval(p) + self.resnet50.size_ratio.eval(p)),
            0.5 * (self.googlenet.inference_ratio.eval(p) + self.resnet50.inference_ratio.eval(p)),
        )
    }

    /// Apply a prune level (fraction in [0,1]) to model metrics.
    pub fn apply(&self, prune: f64, m: &ModelMetrics) -> ModelMetrics {
        let p = prune.clamp(0.0, 1.0);
        let (acc, size, inf) = self.ratios(p);
        ModelMetrics {
            performance: (m.performance * acc).clamp(0.0, 1.0),
            size_mb: (m.size_mb * size).max(0.0),
            inference_ms: (m.inference_ms * inf).max(0.0),
            clever_score: m.clever_score,
            confidence: m.confidence,
            drift: m.drift,
        }
    }

    /// Regenerate Table I from the fitted model and the two base models —
    /// the `pipesim table1` reproduction.
    pub fn regenerate_table1(&self) -> Vec<Table1Row> {
        let base = &TABLE1[0];
        TABLE1
            .iter()
            .map(|row| {
                let p = row.prune_pct / 100.0;
                Table1Row {
                    prune_pct: row.prune_pct,
                    gn_accuracy: base.gn_accuracy * self.googlenet.accuracy_ratio.eval(p),
                    rn50_accuracy: base.rn50_accuracy * self.resnet50.accuracy_ratio.eval(p),
                    gn_size_mb: base.gn_size_mb * self.googlenet.size_ratio.eval(p),
                    rn50_size_mb: base.rn50_size_mb * self.resnet50.size_ratio.eval(p),
                    gn_inference_ms: base.gn_inference_ms * self.googlenet.inference_ratio.eval(p),
                    rn50_inference_ms: base.rn50_inference_ms
                        * self.resnet50.inference_ratio.eval(p),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quad_fit_exact_on_quadratic() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 - 0.5 * x + 0.25 * x * x).collect();
        let q = Quad::fit(&xs, &ys);
        assert!((q.c0 - 2.0).abs() < 1e-9);
        assert!((q.c1 + 0.5).abs() < 1e-9);
        assert!((q.c2 - 0.25).abs() < 1e-9);
    }

    #[test]
    fn solve3_identity() {
        let x = solve3([[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]], [3.0, -1.0, 2.0]);
        assert_eq!(x, [3.0, -1.0, 2.0]);
    }

    #[test]
    fn model_monotone_size_reduction() {
        let m = CompressionModel::from_table1();
        for quads in [&m.googlenet, &m.resnet50] {
            let mut prev = f64::INFINITY;
            for p in [0.0, 0.2, 0.4, 0.6, 0.8] {
                let r = quads.size_ratio.eval(p);
                assert!(r < prev, "size ratio not decreasing at {p}");
                prev = r;
            }
        }
    }

    #[test]
    fn accuracy_degrades_at_high_prune() {
        let m = CompressionModel::from_table1();
        for quads in [&m.googlenet, &m.resnet50] {
            assert!(quads.accuracy_ratio.eval(0.0) > 0.97);
            assert!(quads.accuracy_ratio.eval(0.8) < 0.92);
        }
    }

    #[test]
    fn regenerated_table_close_to_paper() {
        // shape check: regression reproduces Table I within ~8% relative
        let m = CompressionModel::from_table1();
        let regen = m.regenerate_table1();
        for (got, want) in regen.iter().zip(&TABLE1) {
            assert!((got.gn_accuracy - want.gn_accuracy).abs() / want.gn_accuracy < 0.08,
                "acc at {}%: {} vs {}", want.prune_pct, got.gn_accuracy, want.gn_accuracy);
            assert!((got.gn_inference_ms - want.gn_inference_ms).abs() / want.gn_inference_ms < 0.12);
        }
    }

    #[test]
    fn apply_clamps_and_scales() {
        let m = CompressionModel::from_table1();
        let base = ModelMetrics {
            performance: 0.9,
            size_mb: 100.0,
            inference_ms: 50.0,
            ..Default::default()
        };
        let out = m.apply(0.8, &base);
        assert!(out.performance < base.performance);
        assert!(out.size_mb < base.size_mb * 0.4);
        assert!(out.inference_ms < base.inference_ms);
        // extreme prune stays in bounds
        let out2 = m.apply(5.0, &base);
        assert!(out2.performance >= 0.0 && out2.size_mb >= 0.0);
    }
}
