//! Task types, ML frameworks, and model categories.

use std::fmt;

/// Pipeline task types τ (paper section IV-A1a):
/// τ ∈ {preprocess, train, evaluate, compress, harden, deploy}.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum TaskType {
    /// Data preprocessing (runs on the generic compute cluster).
    Preprocess,
    /// Model training (runs on the GPU/learning cluster).
    Train,
    /// Model evaluation / validation.
    Evaluate,
    /// Model compression (learning cluster; ~training cost, section V-A2d).
    Compress,
    /// Robustness hardening (e.g. adversarial training).
    Harden,
    /// Model deployment to serving.
    Deploy,
}

impl TaskType {
    pub const ALL: [TaskType; 6] = [
        TaskType::Preprocess,
        TaskType::Train,
        TaskType::Evaluate,
        TaskType::Compress,
        TaskType::Harden,
        TaskType::Deploy,
    ];

    /// Paper shorthand: the first letter of the type.
    pub fn short(&self) -> char {
        match self {
            TaskType::Preprocess => 'p',
            TaskType::Train => 't',
            TaskType::Evaluate => 'e',
            TaskType::Compress => 'c',
            TaskType::Harden => 'h',
            TaskType::Deploy => 'd',
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TaskType::Preprocess => "preprocess",
            TaskType::Train => "train",
            TaskType::Evaluate => "evaluate",
            TaskType::Compress => "compress",
            TaskType::Harden => "harden",
            TaskType::Deploy => "deploy",
        }
    }

    /// Position in [`TaskType::ALL`] (constant-time).
    #[inline]
    pub fn index(&self) -> usize {
        *self as usize
    }
}

impl fmt::Display for TaskType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// ML frameworks with the production share the paper reports
/// (section IV-B1: 63% SparkML, 32% TensorFlow, 3% PyTorch, 1% Caffe,
/// 1% other).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Framework {
    SparkML,
    TensorFlow,
    PyTorch,
    Caffe,
    Other,
}

impl Framework {
    pub const ALL: [Framework; 5] = [
        Framework::SparkML,
        Framework::TensorFlow,
        Framework::PyTorch,
        Framework::Caffe,
        Framework::Other,
    ];

    /// The paper's observed production mix.
    pub fn paper_share(&self) -> f64 {
        match self {
            Framework::SparkML => 0.63,
            Framework::TensorFlow => 0.32,
            Framework::PyTorch => 0.03,
            Framework::Caffe => 0.01,
            Framework::Other => 0.01,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Framework::SparkML => "sparkml",
            Framework::TensorFlow => "tensorflow",
            Framework::PyTorch => "pytorch",
            Framework::Caffe => "caffe",
            Framework::Other => "other",
        }
    }

    /// Position in [`Framework::ALL`] (constant-time — this sits on the
    /// per-sample hot path of the train-duration pools).
    #[inline]
    pub fn index(&self) -> usize {
        *self as usize
    }
}

impl fmt::Display for Framework {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Prediction type M_t of a trained model (static property).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PredictionType {
    Binary,
    Multiclass,
    Regression,
}

/// Model/estimator type M_e (static property).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelType {
    LinearRegression,
    LogisticRegression,
    RandomForest,
    GradientBoosting,
    NeuralNetwork,
}

impl ModelType {
    pub const ALL: [ModelType; 5] = [
        ModelType::LinearRegression,
        ModelType::LogisticRegression,
        ModelType::RandomForest,
        ModelType::GradientBoosting,
        ModelType::NeuralNetwork,
    ];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shares_sum_to_one() {
        let total: f64 = Framework::ALL.iter().map(|f| f.paper_share()).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn shorthand_letters() {
        assert_eq!(TaskType::Preprocess.short(), 'p');
        assert_eq!(TaskType::Train.short(), 't');
        assert_eq!(TaskType::Evaluate.short(), 'e');
    }

    #[test]
    fn display_names() {
        assert_eq!(TaskType::Train.to_string(), "train");
        assert_eq!(Framework::TensorFlow.to_string(), "tensorflow");
    }

    #[test]
    fn framework_index_roundtrip() {
        for (i, f) in Framework::ALL.iter().enumerate() {
            assert_eq!(f.index(), i);
        }
    }

    #[test]
    fn name_roundtrip() {
        use crate::model::Framework;
        for fw in Framework::ALL {
            assert_eq!(Framework::parse_name(fw.name()).unwrap(), fw);
        }
        assert!(Framework::parse_name("bogus").is_err());
    }
}
