//! Infrastructure resources (paper section IV-A1b): a generic data store
//! plus training and compute clusters, each with a job capacity.

use super::task::TaskType;
use crate::coordinator::strategy::StrategySpec;
use crate::stats::{Dist, Exponential};

/// The kinds of compute resource in the modeled platform.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ResourceKind {
    /// Dedicated training infrastructure (GPU / learning cluster).
    Training,
    /// General-purpose compute (Spark/Hadoop style preprocessing).
    Compute,
}

impl ResourceKind {
    pub fn name(&self) -> &'static str {
        match self {
            ResourceKind::Training => "training",
            ResourceKind::Compute => "compute",
        }
    }

    /// Which cluster each task type executes on.
    pub fn for_task(task: TaskType) -> ResourceKind {
        match task {
            TaskType::Preprocess | TaskType::Evaluate | TaskType::Deploy => ResourceKind::Compute,
            TaskType::Train | TaskType::Compress | TaskType::Harden => ResourceKind::Training,
        }
    }
}

impl std::fmt::Display for ResourceKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The data store abstraction: read/write ops parameterized by bandwidth
/// and latency, with a TCP overhead factor for traffic accounting
/// (the paper's dashboard reports network traffic incl. TCP overhead).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StoreConfig {
    /// Sustained read bandwidth, bytes/s.
    pub read_bw: f64,
    /// Sustained write bandwidth, bytes/s.
    pub write_bw: f64,
    /// Per-operation latency, seconds.
    pub latency: f64,
    /// Multiplier on payload bytes for wire traffic (TCP/framing overhead).
    pub tcp_overhead: f64,
}

impl Default for StoreConfig {
    fn default() -> Self {
        // S3-class object store over 10 GbE
        StoreConfig {
            read_bw: 400e6,
            write_bw: 250e6,
            latency: 0.05,
            tcp_overhead: 1.06,
        }
    }
}

impl StoreConfig {
    /// t(read(A)) for a payload of `bytes`.
    pub fn read_time(&self, bytes: f64) -> f64 {
        self.latency + bytes / self.read_bw
    }

    /// t(write(A)).
    pub fn write_time(&self, bytes: f64) -> f64 {
        self.latency + bytes / self.write_bw
    }

    /// Wire bytes including protocol overhead.
    pub fn wire_bytes(&self, bytes: f64) -> f64 {
        bytes * self.tcp_overhead
    }
}

/// Failure behavior of one cluster: slot failures arrive with
/// inter-failure times drawn from `mtbf`, each failed slot comes back
/// after a repair time drawn from `mttr`. An interrupted task loses the
/// service tail since its last checkpoint (every `checkpoint_interval`
/// seconds of *attempt* progress) and pays `restart_cost` extra service
/// on top; with checkpointing off (`checkpoint_interval == 0`) the whole
/// attempt so far is lost.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterFailureConfig {
    /// Distribution of times between slot failures, seconds.
    pub mtbf: Dist,
    /// Distribution of per-slot repair times, seconds.
    pub mttr: Dist,
    /// Checkpoint period in seconds of task progress; `0.0` disables
    /// checkpointing (an interrupted attempt is lost entirely).
    pub checkpoint_interval: f64,
    /// Fixed extra service a restarted task pays (state reload, requeue
    /// overheads), seconds.
    pub restart_cost: f64,
}

impl ClusterFailureConfig {
    /// Memoryless failures/repairs with the given mean times, the
    /// standard reliability-model baseline.
    pub fn exponential(mtbf_mean: f64, mttr_mean: f64) -> Self {
        assert!(mtbf_mean > 0.0 && mttr_mean > 0.0);
        ClusterFailureConfig {
            mtbf: Dist::Exponential(Exponential::new(1.0 / mtbf_mean)),
            mttr: Dist::Exponential(Exponential::new(1.0 / mttr_mean)),
            checkpoint_interval: 0.0,
            restart_cost: 0.0,
        }
    }

    /// Builder-style checkpointing knob.
    pub fn with_checkpointing(mut self, interval: f64, restart_cost: f64) -> Self {
        self.checkpoint_interval = interval;
        self.restart_cost = restart_cost;
        self
    }
}

/// Per-cluster failure injection; `None` for a cluster means it never
/// fails. The whole model is optional on [`InfraConfig`] — the default
/// (`None`) keeps the simulation's event stream and digests byte-for-byte
/// identical to a build without the subsystem.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FailureModel {
    pub training: Option<ClusterFailureConfig>,
    pub compute: Option<ClusterFailureConfig>,
}

impl FailureModel {
    /// Same failure behavior on both clusters.
    pub fn uniform(cfg: ClusterFailureConfig) -> Self {
        FailureModel {
            training: Some(cfg.clone()),
            compute: Some(cfg),
        }
    }

    pub fn for_kind(&self, kind: ResourceKind) -> Option<&ClusterFailureConfig> {
        match kind {
            ResourceKind::Training => self.training.as_ref(),
            ResourceKind::Compute => self.compute.as_ref(),
        }
    }

    /// True when neither cluster can fail (equivalent to `failures: None`).
    pub fn is_empty(&self) -> bool {
        self.training.is_none() && self.compute.is_none()
    }
}

/// Task-level fault behavior of one cluster (the complement of
/// [`ClusterFailureConfig`], which models *infrastructure* failures):
/// each running attempt independently draws a fault time from
/// `fault_time` and fails transiently if that lands before the attempt
/// completes; attempts running longer than `timeout` are killed; and
/// fresh pipelines arriving while the cluster's wait queue holds
/// `queue_cap` or more jobs are shed outright (admission control).
/// What happens after a fault/timeout is the retry policy's call
/// (see [`FaultModel::retry`]).
#[derive(Clone, Debug, PartialEq)]
pub struct TaskFaultConfig {
    /// Distribution of per-attempt transient-fault times, seconds.
    /// `None` disables transient faults (timeout/shedding still apply),
    /// and — because fault times are drawn on a dedicated RNG substream
    /// only when this is set — leaves every other stream untouched.
    pub fault_time: Option<Dist>,
    /// Per-attempt wall-clock budget, seconds; attempts still running
    /// after this are killed and routed through the retry policy.
    /// `0.0` disables timeouts.
    pub timeout: f64,
    /// Admission-control bound on the cluster's wait queue: a fresh
    /// pipeline whose first task would queue behind `queue_cap` or more
    /// waiting jobs is shed (terminal outcome, no retry). `0` disables
    /// shedding. Retries and mid-pipeline tasks are always admitted.
    pub queue_cap: u64,
}

impl Default for TaskFaultConfig {
    fn default() -> Self {
        TaskFaultConfig {
            fault_time: None,
            timeout: 0.0,
            queue_cap: 0,
        }
    }
}

impl TaskFaultConfig {
    /// Memoryless transient faults with the given mean time-to-fault,
    /// the standard reliability baseline.
    pub fn transient(mean_time_to_fault: f64) -> Self {
        assert!(mean_time_to_fault > 0.0);
        TaskFaultConfig {
            fault_time: Some(Dist::Exponential(Exponential::new(1.0 / mean_time_to_fault))),
            ..Default::default()
        }
    }

    /// Builder-style per-attempt timeout.
    pub fn with_timeout(mut self, timeout: f64) -> Self {
        self.timeout = timeout;
        self
    }

    /// Builder-style admission-control queue cap.
    pub fn with_queue_cap(mut self, cap: u64) -> Self {
        self.queue_cap = cap;
        self
    }

    /// True when every knob is off — behaviorally identical to no
    /// fault config at all.
    pub fn is_inert(&self) -> bool {
        self.fault_time.is_none() && self.timeout == 0.0 && self.queue_cap == 0
    }
}

/// Per-cluster task-fault injection plus the retry policy that decides
/// what happens after each fault or timeout. `None` for a cluster means
/// its tasks never fault. The whole model is optional on
/// [`InfraConfig`] — the default (`None`) draws nothing from the fault
/// RNG substream and keeps every pre-existing digest byte-identical.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultModel {
    pub training: Option<TaskFaultConfig>,
    pub compute: Option<TaskFaultConfig>,
    /// Retry strategy consulted after every task fault/timeout (see
    /// `coordinator::strategy::retry_policy_names`). Default `always`.
    pub retry: StrategySpec,
}

impl Default for FaultModel {
    fn default() -> Self {
        FaultModel {
            training: None,
            compute: None,
            retry: StrategySpec::new("always"),
        }
    }
}

impl FaultModel {
    /// Same fault behavior on both clusters.
    pub fn uniform(cfg: TaskFaultConfig) -> Self {
        FaultModel {
            training: Some(cfg.clone()),
            compute: Some(cfg),
            ..Default::default()
        }
    }

    pub fn for_kind(&self, kind: ResourceKind) -> Option<&TaskFaultConfig> {
        match kind {
            ResourceKind::Training => self.training.as_ref(),
            ResourceKind::Compute => self.compute.as_ref(),
        }
    }

    /// True when no cluster can fault (equivalent to `faults: None`;
    /// the retry spec is irrelevant when nothing ever fails).
    pub fn is_empty(&self) -> bool {
        self.training.is_none() && self.compute.is_none()
    }
}

/// One hardware class inside a cluster: a named group of slots with a
/// common execution-speed profile and price. Classes model mixed fleets —
/// GPU generations, CPU pools, spot vs reserved capacity — where both
/// how fast a task runs and what it costs depend on *where* it lands
/// (the offline-profiling simulation approach: per-(framework,
/// hw-class) profiled speeds instead of one fitted distribution).
#[derive(Clone, Debug, PartialEq)]
pub struct HwClass {
    /// Class name, unique within its cluster (e.g. `"a100"`, `"spot"`).
    pub name: String,
    /// Slots of this class. Per-cluster class slot counts must sum to
    /// the cluster's capacity (validated by `ExperimentConfig`).
    pub slots: usize,
    /// Execution-speed factor: sampled service time is divided by this,
    /// so `2.0` runs tasks twice as fast and `1.0` is the homogeneous
    /// baseline (bit-exact: `x / 1.0 == x`).
    pub speed: f64,
    /// Price of one busy slot-second, accrued into
    /// `ExperimentResult::cost` (outside the digest). `0.0` = free.
    pub cost_per_sec: f64,
    /// Per-framework speed overrides `(framework name, speed)` — the
    /// profile-driven execution model. A task tagged with a listed
    /// framework uses that speed instead of [`HwClass::speed`]; gang
    /// jobs spanning classes run at the slowest allocated class.
    pub fw_speed: Vec<(String, f64)>,
    /// Per-class failure behavior (MTBF/MTTR on this class's slots
    /// only), independent of any cluster-level [`FailureModel`].
    pub failures: Option<ClusterFailureConfig>,
}

impl HwClass {
    /// A class with uniform speed 1.0 and no cost — indistinguishable
    /// from homogeneous slots.
    pub fn new(name: impl Into<String>, slots: usize) -> Self {
        HwClass {
            name: name.into(),
            slots,
            speed: 1.0,
            cost_per_sec: 0.0,
            fw_speed: Vec::new(),
            failures: None,
        }
    }

    /// Builder-style speed factor.
    pub fn with_speed(mut self, speed: f64) -> Self {
        self.speed = speed;
        self
    }

    /// Builder-style cost knob.
    pub fn with_cost(mut self, cost_per_sec: f64) -> Self {
        self.cost_per_sec = cost_per_sec;
        self
    }

    /// Builder-style per-framework profiled speed.
    pub fn with_fw_speed(mut self, fw: impl Into<String>, speed: f64) -> Self {
        self.fw_speed.push((fw.into(), speed));
        self
    }

    /// Builder-style per-class failure behavior.
    pub fn with_failures(mut self, fc: ClusterFailureConfig) -> Self {
        self.failures = Some(fc);
        self
    }

    /// Effective speed for a task tagged with framework `fw` (`None` =
    /// untagged → the class-wide factor).
    pub fn speed_for(&self, fw: Option<&str>) -> f64 {
        if let Some(fw) = fw {
            for (name, s) in &self.fw_speed {
                if name == fw {
                    return *s;
                }
            }
        }
        self.speed
    }
}

/// Hardware classes of both clusters plus the placement strategy that
/// assigns granted jobs to classes. An empty class list for a cluster
/// means that cluster stays a homogeneous pool. The whole struct is
/// optional on [`InfraConfig`]: `None` (the default) keeps the
/// simulation's event stream and digests byte-for-byte identical to a
/// build without the subsystem.
#[derive(Clone, Debug, PartialEq)]
pub struct HwClasses {
    /// Classes of the training cluster (slot counts must sum to
    /// `training_capacity`; empty = homogeneous).
    pub training: Vec<HwClass>,
    /// Classes of the compute cluster (slot counts must sum to
    /// `compute_capacity`; empty = homogeneous).
    pub compute: Vec<HwClass>,
    /// Placement strategy choosing which class a granted job runs on
    /// (see `coordinator::strategy::placer_names`).
    pub placer: StrategySpec,
}

impl Default for HwClasses {
    fn default() -> Self {
        HwClasses {
            training: Vec::new(),
            compute: Vec::new(),
            placer: StrategySpec::new("fastest_fit"),
        }
    }
}

impl HwClasses {
    pub fn for_kind(&self, kind: ResourceKind) -> &[HwClass] {
        match kind {
            ResourceKind::Training => &self.training,
            ResourceKind::Compute => &self.compute,
        }
    }

    /// True when neither cluster has classes (equivalent to
    /// `hw_classes: None`).
    pub fn is_empty(&self) -> bool {
        self.training.is_empty() && self.compute.is_empty()
    }
}

/// Full infrastructure configuration for an experiment.
#[derive(Clone, Debug, PartialEq)]
pub struct InfraConfig {
    /// Job capacity of the training (learning) cluster.
    pub training_capacity: usize,
    /// Job capacity of the generic compute cluster.
    pub compute_capacity: usize,
    /// Slots a training task occupies on the training cluster (a
    /// gang-scheduled multi-accelerator job). Default 1 — every task is
    /// single-slot and queue behavior is unchanged. Values above 1 mix
    /// wide training jobs with single-slot compress/harden work on the
    /// same cluster, which is what gives backfill schedulers
    /// (`easy_backfill`) a blocked head-of-queue to reserve around.
    pub train_slots: usize,
    /// Shared scheduling strategy for both clusters (each cluster builds
    /// its own instance from the spec — see `coordinator::strategy`).
    /// Per-cluster overrides below take precedence where set.
    pub scheduler: StrategySpec,
    /// Training-cluster override of [`InfraConfig::scheduler`]
    /// (`None` → the shared spec). Backfill and gang-scheduling
    /// strategies mainly matter here, so a split lets e.g.
    /// `easy_backfill` drive training while compute stays FIFO.
    pub scheduler_training: Option<StrategySpec>,
    /// Compute-cluster override of [`InfraConfig::scheduler`]
    /// (`None` → the shared spec).
    pub scheduler_compute: Option<StrategySpec>,
    /// Failure injection (`None` → a perfectly reliable platform; this
    /// is the default and keeps every pre-existing digest byte-identical).
    pub failures: Option<FailureModel>,
    /// Hardware classes + placement strategy (`None` → homogeneous
    /// pools; this is the default and keeps every pre-existing digest
    /// byte-identical).
    pub hw_classes: Option<HwClasses>,
    /// Task-level fault injection + retry policy (`None` → tasks never
    /// fault; this is the default and keeps every pre-existing digest
    /// byte-identical).
    pub faults: Option<FaultModel>,
    pub store: StoreConfig,
}

impl Default for InfraConfig {
    fn default() -> Self {
        InfraConfig {
            training_capacity: 10,
            compute_capacity: 20,
            train_slots: 1,
            scheduler: StrategySpec::new("fifo"),
            scheduler_training: None,
            scheduler_compute: None,
            failures: None,
            hw_classes: None,
            faults: None,
            store: StoreConfig::default(),
        }
    }
}

impl InfraConfig {
    pub fn capacity(&self, kind: ResourceKind) -> usize {
        match kind {
            ResourceKind::Training => self.training_capacity,
            ResourceKind::Compute => self.compute_capacity,
        }
    }

    /// The scheduler spec that drives `kind`'s cluster: the per-cluster
    /// override when set, else the shared [`InfraConfig::scheduler`].
    pub fn scheduler_for(&self, kind: ResourceKind) -> &StrategySpec {
        let over = match kind {
            ResourceKind::Training => &self.scheduler_training,
            ResourceKind::Compute => &self.scheduler_compute,
        };
        over.as_ref().unwrap_or(&self.scheduler)
    }

    /// Compact strategy label for reports and trace metadata: the shared
    /// spec's label when no override is set (pre-split behavior, so
    /// existing trace files stay byte-identical), else both resolved
    /// labels.
    pub fn scheduler_label(&self) -> String {
        if self.scheduler_training.is_none() && self.scheduler_compute.is_none() {
            return self.scheduler.label();
        }
        format!(
            "training={}|compute={}",
            self.scheduler_for(ResourceKind::Training).label(),
            self.scheduler_for(ResourceKind::Compute).label()
        )
    }

    /// Failure behavior of `kind`'s cluster, when any is configured.
    pub fn failure_for(&self, kind: ResourceKind) -> Option<&ClusterFailureConfig> {
        self.failures.as_ref().and_then(|f| f.for_kind(kind))
    }

    /// Slots a task occupies on its cluster.
    pub fn task_slots(&self, task: TaskType) -> u32 {
        if task == TaskType::Train {
            self.train_slots as u32
        } else {
            1
        }
    }

    /// Hardware classes of `kind`'s cluster, when any are configured
    /// (an empty class list counts as homogeneous).
    pub fn hw_classes_for(&self, kind: ResourceKind) -> Option<&[HwClass]> {
        match &self.hw_classes {
            Some(hw) => {
                let classes = hw.for_kind(kind);
                if classes.is_empty() {
                    None
                } else {
                    Some(classes)
                }
            }
            None => None,
        }
    }

    /// Compact placer label for reports and trace metadata; `None` when
    /// no hardware classes are configured (so pre-PR trace metadata is
    /// byte-identical).
    pub fn placer_label(&self) -> Option<String> {
        match &self.hw_classes {
            Some(hw) if !hw.is_empty() => Some(hw.placer.label()),
            _ => None,
        }
    }

    /// Task-fault behavior of `kind`'s cluster, when any is configured.
    pub fn fault_for(&self, kind: ResourceKind) -> Option<&TaskFaultConfig> {
        self.faults.as_ref().and_then(|f| f.for_kind(kind))
    }

    /// The retry-policy spec, when a fault model is configured.
    pub fn retry_spec(&self) -> Option<&StrategySpec> {
        self.faults.as_ref().map(|f| &f.retry)
    }

    /// Compact retry-policy label for reports and trace metadata;
    /// `None` when no fault model is configured (so pre-PR trace
    /// metadata is byte-identical).
    pub fn retry_label(&self) -> Option<String> {
        match &self.faults {
            Some(f) if !f.is_empty() => Some(f.retry.label()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_to_resource_mapping() {
        assert_eq!(ResourceKind::for_task(TaskType::Train), ResourceKind::Training);
        assert_eq!(ResourceKind::for_task(TaskType::Compress), ResourceKind::Training);
        assert_eq!(ResourceKind::for_task(TaskType::Preprocess), ResourceKind::Compute);
        assert_eq!(ResourceKind::for_task(TaskType::Evaluate), ResourceKind::Compute);
    }

    #[test]
    fn store_times_scale_with_bytes() {
        let s = StoreConfig::default();
        let t1 = s.read_time(1e6);
        let t2 = s.read_time(2e6);
        assert!(t2 > t1);
        assert!(t1 > s.latency);
        assert!(s.write_time(1e6) > s.read_time(1e6)); // write bw lower
    }

    #[test]
    fn wire_bytes_include_overhead() {
        let s = StoreConfig::default();
        assert!((s.wire_bytes(100.0) - 106.0).abs() < 1e-9);
    }

    #[test]
    fn config_capacity_lookup() {
        let c = InfraConfig {
            training_capacity: 4,
            compute_capacity: 9,
            ..Default::default()
        };
        assert_eq!(c.capacity(ResourceKind::Training), 4);
        assert_eq!(c.capacity(ResourceKind::Compute), 9);
    }

    #[test]
    fn json_roundtrip() {
        use crate::util::jsonio::JsonIo;
        let c = InfraConfig::default();
        let back =
            InfraConfig::from_json(&crate::util::Json::parse(&c.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn per_resource_specs_resolve_and_label() {
        let mut c = InfraConfig::default();
        // no overrides: both clusters share the spec, label is pre-split
        assert_eq!(c.scheduler_for(ResourceKind::Training).name, "fifo");
        assert_eq!(c.scheduler_for(ResourceKind::Compute).name, "fifo");
        assert_eq!(c.scheduler_label(), "fifo");
        // training override: compute still follows the shared spec
        c.scheduler_training = Some(StrategySpec::new("easy_backfill"));
        assert_eq!(
            c.scheduler_for(ResourceKind::Training).name,
            "easy_backfill"
        );
        assert_eq!(c.scheduler_for(ResourceKind::Compute).name, "fifo");
        assert_eq!(c.scheduler_label(), "training=easy_backfill|compute=fifo");
        c.scheduler_compute = Some(StrategySpec::new("sjf"));
        assert_eq!(c.scheduler_label(), "training=easy_backfill|compute=sjf");
    }

    #[test]
    fn per_resource_specs_roundtrip_json_and_stay_optional() {
        use crate::util::jsonio::JsonIo;
        let mut c = InfraConfig::default();
        c.scheduler_training = Some(StrategySpec::new("priority"));
        c.scheduler_compute = Some(StrategySpec::new("edf").with("slack_per_class", 60.0));
        let back =
            InfraConfig::from_json(&crate::util::Json::parse(&c.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(c, back);
        // the default emits no override keys, so pre-split configs (and
        // the config JSON embedded in existing trace files) are unchanged
        let plain = InfraConfig::default().to_json().to_string();
        assert!(!plain.contains("scheduler_training"), "{plain}");
        assert!(!plain.contains("scheduler_compute"), "{plain}");
        assert!(!plain.contains("failures"), "{plain}");
    }

    #[test]
    fn failure_model_roundtrips_json_and_stays_optional() {
        use crate::util::jsonio::JsonIo;
        let mut c = InfraConfig::default();
        c.failures = Some(FailureModel {
            training: Some(
                ClusterFailureConfig::exponential(3600.0, 120.0).with_checkpointing(300.0, 30.0),
            ),
            compute: None,
        });
        let back =
            InfraConfig::from_json(&crate::util::Json::parse(&c.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(c, back);
        assert_eq!(
            c.failure_for(ResourceKind::Training)
                .map(|f| f.checkpoint_interval),
            Some(300.0)
        );
        assert!(c.failure_for(ResourceKind::Compute).is_none());
    }

    #[test]
    fn hw_classes_roundtrip_json_and_stay_optional() {
        use crate::util::jsonio::JsonIo;
        let mut c = InfraConfig::default();
        c.training_capacity = 6;
        c.hw_classes = Some(HwClasses {
            training: vec![
                HwClass::new("a100", 2)
                    .with_speed(2.0)
                    .with_cost(3.0)
                    .with_fw_speed("tensorflow", 2.5),
                HwClass::new("v100", 4)
                    .with_failures(ClusterFailureConfig::exponential(7200.0, 60.0)),
            ],
            compute: Vec::new(),
            placer: StrategySpec::new("cheapest_fit"),
        });
        let back =
            InfraConfig::from_json(&crate::util::Json::parse(&c.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(c, back);
        // the default emits no hw_classes key, so pre-PR config JSON
        // (and the config embedded in existing traces) is unchanged
        let plain = InfraConfig::default().to_json().to_string();
        assert!(!plain.contains("hw_classes"), "{plain}");
    }

    #[test]
    fn hw_class_speed_profile_resolution() {
        let c = HwClass::new("a100", 2)
            .with_speed(2.0)
            .with_fw_speed("tensorflow", 3.0);
        assert_eq!(c.speed_for(None), 2.0);
        assert_eq!(c.speed_for(Some("pytorch")), 2.0);
        assert_eq!(c.speed_for(Some("tensorflow")), 3.0);
    }

    #[test]
    fn hw_classes_accessors() {
        let mut c = InfraConfig::default();
        assert!(c.hw_classes_for(ResourceKind::Training).is_none());
        assert!(c.placer_label().is_none());
        c.training_capacity = 3;
        c.hw_classes = Some(HwClasses {
            training: vec![HwClass::new("gpu", 3)],
            compute: Vec::new(),
            placer: StrategySpec::new("pack"),
        });
        assert_eq!(
            c.hw_classes_for(ResourceKind::Training).map(|s| s.len()),
            Some(1)
        );
        // compute has no classes: it stays a homogeneous pool
        assert!(c.hw_classes_for(ResourceKind::Compute).is_none());
        assert_eq!(c.placer_label().as_deref(), Some("pack"));
    }

    #[test]
    fn fault_model_roundtrips_json_and_stays_optional() {
        use crate::util::jsonio::JsonIo;
        let mut c = InfraConfig::default();
        c.faults = Some(FaultModel {
            training: Some(
                TaskFaultConfig::transient(3600.0)
                    .with_timeout(1800.0)
                    .with_queue_cap(16),
            ),
            compute: None,
            retry: StrategySpec::new("exp_backoff").with("max_attempts", 4.0),
        });
        let back =
            InfraConfig::from_json(&crate::util::Json::parse(&c.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(c, back);
        assert_eq!(
            c.fault_for(ResourceKind::Training).map(|f| f.queue_cap),
            Some(16)
        );
        assert!(c.fault_for(ResourceKind::Compute).is_none());
        assert_eq!(c.retry_label().as_deref(), Some("exp_backoff:max_attempts=4"));
        // the default emits no faults key, so pre-PR config JSON (and
        // the config embedded in existing traces) is unchanged
        let plain = InfraConfig::default().to_json().to_string();
        assert!(!plain.contains("faults"), "{plain}");
    }

    #[test]
    fn fault_model_helpers() {
        let f = FaultModel::uniform(TaskFaultConfig::transient(1e4));
        assert!(!f.is_empty());
        assert!(f.for_kind(ResourceKind::Training).is_some());
        assert!(f.for_kind(ResourceKind::Compute).is_some());
        assert_eq!(f.retry.name, "always");
        assert!(FaultModel::default().is_empty());
        assert!(TaskFaultConfig::default().is_inert());
        assert!(!TaskFaultConfig::transient(100.0).is_inert());
        assert!(!TaskFaultConfig::default().with_queue_cap(1).is_inert());
        // no fault model → no retry label, like placer_label
        let c = InfraConfig::default();
        assert!(c.retry_label().is_none());
        assert!(c.retry_spec().is_none());
        assert!(c.fault_for(ResourceKind::Training).is_none());
    }

    #[test]
    fn failure_model_helpers() {
        let f = FailureModel::uniform(ClusterFailureConfig::exponential(1e4, 60.0));
        assert!(!f.is_empty());
        assert!(f.for_kind(ResourceKind::Training).is_some());
        assert!(f.for_kind(ResourceKind::Compute).is_some());
        assert!(FailureModel::default().is_empty());
    }
}
