//! Infrastructure resources (paper section IV-A1b): a generic data store
//! plus training and compute clusters, each with a job capacity.

use super::task::TaskType;
use crate::coordinator::strategy::StrategySpec;

/// The kinds of compute resource in the modeled platform.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ResourceKind {
    /// Dedicated training infrastructure (GPU / learning cluster).
    Training,
    /// General-purpose compute (Spark/Hadoop style preprocessing).
    Compute,
}

impl ResourceKind {
    pub fn name(&self) -> &'static str {
        match self {
            ResourceKind::Training => "training",
            ResourceKind::Compute => "compute",
        }
    }

    /// Which cluster each task type executes on.
    pub fn for_task(task: TaskType) -> ResourceKind {
        match task {
            TaskType::Preprocess | TaskType::Evaluate | TaskType::Deploy => ResourceKind::Compute,
            TaskType::Train | TaskType::Compress | TaskType::Harden => ResourceKind::Training,
        }
    }
}

impl std::fmt::Display for ResourceKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The data store abstraction: read/write ops parameterized by bandwidth
/// and latency, with a TCP overhead factor for traffic accounting
/// (the paper's dashboard reports network traffic incl. TCP overhead).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StoreConfig {
    /// Sustained read bandwidth, bytes/s.
    pub read_bw: f64,
    /// Sustained write bandwidth, bytes/s.
    pub write_bw: f64,
    /// Per-operation latency, seconds.
    pub latency: f64,
    /// Multiplier on payload bytes for wire traffic (TCP/framing overhead).
    pub tcp_overhead: f64,
}

impl Default for StoreConfig {
    fn default() -> Self {
        // S3-class object store over 10 GbE
        StoreConfig {
            read_bw: 400e6,
            write_bw: 250e6,
            latency: 0.05,
            tcp_overhead: 1.06,
        }
    }
}

impl StoreConfig {
    /// t(read(A)) for a payload of `bytes`.
    pub fn read_time(&self, bytes: f64) -> f64 {
        self.latency + bytes / self.read_bw
    }

    /// t(write(A)).
    pub fn write_time(&self, bytes: f64) -> f64 {
        self.latency + bytes / self.write_bw
    }

    /// Wire bytes including protocol overhead.
    pub fn wire_bytes(&self, bytes: f64) -> f64 {
        bytes * self.tcp_overhead
    }
}

/// Full infrastructure configuration for an experiment.
#[derive(Clone, Debug, PartialEq)]
pub struct InfraConfig {
    /// Job capacity of the training (learning) cluster.
    pub training_capacity: usize,
    /// Job capacity of the generic compute cluster.
    pub compute_capacity: usize,
    /// Slots a training task occupies on the training cluster (a
    /// gang-scheduled multi-accelerator job). Default 1 — every task is
    /// single-slot and queue behavior is unchanged. Values above 1 mix
    /// wide training jobs with single-slot compress/harden work on the
    /// same cluster, which is what gives backfill schedulers
    /// (`easy_backfill`) a blocked head-of-queue to reserve around.
    pub train_slots: usize,
    /// Scheduling strategy for both clusters (each cluster builds its
    /// own instance from the spec — see `coordinator::strategy`).
    pub scheduler: StrategySpec,
    pub store: StoreConfig,
}

impl Default for InfraConfig {
    fn default() -> Self {
        InfraConfig {
            training_capacity: 10,
            compute_capacity: 20,
            train_slots: 1,
            scheduler: StrategySpec::new("fifo"),
            store: StoreConfig::default(),
        }
    }
}

impl InfraConfig {
    pub fn capacity(&self, kind: ResourceKind) -> usize {
        match kind {
            ResourceKind::Training => self.training_capacity,
            ResourceKind::Compute => self.compute_capacity,
        }
    }

    /// Slots a task occupies on its cluster.
    pub fn task_slots(&self, task: TaskType) -> u32 {
        if task == TaskType::Train {
            self.train_slots as u32
        } else {
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_to_resource_mapping() {
        assert_eq!(ResourceKind::for_task(TaskType::Train), ResourceKind::Training);
        assert_eq!(ResourceKind::for_task(TaskType::Compress), ResourceKind::Training);
        assert_eq!(ResourceKind::for_task(TaskType::Preprocess), ResourceKind::Compute);
        assert_eq!(ResourceKind::for_task(TaskType::Evaluate), ResourceKind::Compute);
    }

    #[test]
    fn store_times_scale_with_bytes() {
        let s = StoreConfig::default();
        let t1 = s.read_time(1e6);
        let t2 = s.read_time(2e6);
        assert!(t2 > t1);
        assert!(t1 > s.latency);
        assert!(s.write_time(1e6) > s.read_time(1e6)); // write bw lower
    }

    #[test]
    fn wire_bytes_include_overhead() {
        let s = StoreConfig::default();
        assert!((s.wire_bytes(100.0) - 106.0).abs() < 1e-9);
    }

    #[test]
    fn config_capacity_lookup() {
        let c = InfraConfig {
            training_capacity: 4,
            compute_capacity: 9,
            ..Default::default()
        };
        assert_eq!(c.capacity(ResourceKind::Training), 4);
        assert_eq!(c.capacity(ResourceKind::Compute), 9);
    }

    #[test]
    fn json_roundtrip() {
        use crate::util::jsonio::JsonIo;
        let c = InfraConfig::default();
        let back =
            InfraConfig::from_json(&crate::util::Json::parse(&c.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(c, back);
    }
}
