//! Pipelines as task digraphs (paper section IV-A1a).
//!
//! A pipeline G_p = (V_p, E_p) with typed task vertices. The simulator
//! executes tasks sequentially (the paper's current system model assumes
//! no intra-pipeline parallelism), so the digraph is validated and then
//! linearized into an execution order.

use super::task::{Framework, TaskType};
use crate::error::{Error, Result};

/// Identifier of one pipeline execution.
pub type PipelineId = u64;

/// A task vertex with its type-specific attributes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TaskNode {
    pub task: TaskType,
    /// Training framework (train/compress/harden tasks).
    pub framework: Option<Framework>,
}

impl TaskNode {
    pub fn new(task: TaskType) -> Self {
        TaskNode {
            task,
            framework: None,
        }
    }

    pub fn with_framework(task: TaskType, fw: Framework) -> Self {
        TaskNode {
            task,
            framework: Some(fw),
        }
    }
}

/// A pipeline structure: vertices + directed edges (indices into `nodes`).
#[derive(Clone, Debug, PartialEq)]
pub struct Pipeline {
    pub nodes: Vec<TaskNode>,
    pub edges: Vec<(usize, usize)>,
}

impl Pipeline {
    /// A linear pipeline from an ordered task list.
    pub fn linear(nodes: Vec<TaskNode>) -> Self {
        let edges = (1..nodes.len()).map(|i| (i - 1, i)).collect();
        Pipeline { nodes, edges }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn has_task(&self, t: TaskType) -> bool {
        self.nodes.iter().any(|n| n.task == t)
    }

    pub fn framework(&self) -> Option<Framework> {
        self.nodes.iter().find_map(|n| n.framework)
    }

    /// Topological order (Kahn). Errors on cycles.
    pub fn topo_order(&self) -> Result<Vec<usize>> {
        let n = self.nodes.len();
        let mut indeg = vec![0usize; n];
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        for &(a, b) in &self.edges {
            if a >= n || b >= n {
                return Err(Error::Config(format!("edge ({a},{b}) out of range")));
            }
            adj[a].push(b);
            indeg[b] += 1;
        }
        let mut queue: std::collections::VecDeque<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(v) = queue.pop_front() {
            order.push(v);
            for &w in &adj[v] {
                indeg[w] -= 1;
                if indeg[w] == 0 {
                    queue.push_back(w);
                }
            }
        }
        if order.len() != n {
            return Err(Error::Config("pipeline digraph has a cycle".into()));
        }
        Ok(order)
    }

    /// Structural validity (the synthesizer's "sensible pipeline" rules,
    /// section IV-B1): a generating pipeline needs a train task; anything
    /// operating on a model (evaluate/compress/harden/deploy) must come
    /// after training; preprocess must precede training.
    pub fn validate(&self) -> Result<()> {
        let order = self.topo_order()?;
        let pos: Vec<usize> = {
            let mut p = vec![0; self.nodes.len()];
            for (rank, &v) in order.iter().enumerate() {
                p[v] = rank;
            }
            p
        };
        let train_pos = self
            .nodes
            .iter()
            .position(|nd| nd.task == TaskType::Train)
            .ok_or_else(|| Error::Config("pipeline lacks a train task".into()))?;
        let train_rank = pos[train_pos];
        for (i, nd) in self.nodes.iter().enumerate() {
            match nd.task {
                TaskType::Preprocess => {
                    if pos[i] > train_rank {
                        return Err(Error::Config("preprocess after train".into()));
                    }
                }
                TaskType::Evaluate | TaskType::Compress | TaskType::Harden | TaskType::Deploy => {
                    if pos[i] < train_rank {
                        return Err(Error::Config(format!("{} before train", nd.task)));
                    }
                }
                TaskType::Train => {}
            }
        }
        // train/compress/harden need a framework assignment
        for nd in &self.nodes {
            if matches!(nd.task, TaskType::Train | TaskType::Compress | TaskType::Harden)
                && nd.framework.is_none()
            {
                return Err(Error::Config(format!("{} lacks framework", nd.task)));
            }
        }
        Ok(())
    }

    /// The sequential execution order of task indices.
    pub fn execution_order(&self) -> Result<Vec<usize>> {
        self.validate()?;
        self.topo_order()
    }

    /// Compact signature like "p-t-e-d" (paper's shorthand).
    pub fn signature(&self) -> String {
        self.topo_order()
            .map(|o| {
                o.iter()
                    .map(|&i| self.nodes[i].task.short().to_string())
                    .collect::<Vec<_>>()
                    .join("-")
            })
            .unwrap_or_else(|_| "<cyclic>".into())
    }
}

/// The prototypical pipeline structures of Fig 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipelineTemplate {
    /// Fig 1(1): process – train – validate – deploy.
    Simple,
    /// Fig 1(2): extended with custom steps (compress / harden).
    Extended,
    /// Fig 1(3): hierarchical with transfer learning (base model train
    /// feeding a fine-tune train).
    Hierarchical,
}

impl PipelineTemplate {
    pub fn build(&self, fw: Framework) -> Pipeline {
        use TaskType::*;
        match self {
            PipelineTemplate::Simple => Pipeline::linear(vec![
                TaskNode::new(Preprocess),
                TaskNode::with_framework(Train, fw),
                TaskNode::new(Evaluate),
                TaskNode::new(Deploy),
            ]),
            PipelineTemplate::Extended => Pipeline::linear(vec![
                TaskNode::new(Preprocess),
                TaskNode::with_framework(Train, fw),
                TaskNode::new(Evaluate),
                TaskNode::with_framework(Compress, fw),
                TaskNode::with_framework(Harden, fw),
                TaskNode::new(Evaluate),
                TaskNode::new(Deploy),
            ]),
            PipelineTemplate::Hierarchical => Pipeline::linear(vec![
                TaskNode::new(Preprocess),
                TaskNode::with_framework(Train, fw), // base model
                TaskNode::with_framework(Train, fw), // transfer fine-tune
                TaskNode::new(Evaluate),
                TaskNode::new(Deploy),
            ]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_pipeline_valid() {
        let p = PipelineTemplate::Simple.build(Framework::SparkML);
        p.validate().unwrap();
        assert_eq!(p.signature(), "p-t-e-d");
    }

    #[test]
    fn all_templates_valid() {
        for t in [
            PipelineTemplate::Simple,
            PipelineTemplate::Extended,
            PipelineTemplate::Hierarchical,
        ] {
            t.build(Framework::TensorFlow).validate().unwrap();
        }
    }

    #[test]
    fn rejects_eval_before_train() {
        let p = Pipeline::linear(vec![
            TaskNode::new(TaskType::Evaluate),
            TaskNode::with_framework(TaskType::Train, Framework::Caffe),
        ]);
        assert!(p.validate().is_err());
    }

    #[test]
    fn rejects_missing_train() {
        let p = Pipeline::linear(vec![TaskNode::new(TaskType::Preprocess)]);
        assert!(p.validate().is_err());
    }

    #[test]
    fn rejects_cycle() {
        let mut p = PipelineTemplate::Simple.build(Framework::SparkML);
        p.edges.push((3, 0));
        assert!(p.topo_order().is_err());
    }

    #[test]
    fn rejects_train_without_framework() {
        let p = Pipeline::linear(vec![TaskNode::new(TaskType::Train)]);
        assert!(p.validate().is_err());
    }

    #[test]
    fn topo_order_respects_edges() {
        // diamond: 0 -> {1,2} -> 3
        let p = Pipeline {
            nodes: vec![
                TaskNode::new(TaskType::Preprocess),
                TaskNode::with_framework(TaskType::Train, Framework::SparkML),
                TaskNode::new(TaskType::Evaluate),
                TaskNode::new(TaskType::Deploy),
            ],
            edges: vec![(0, 1), (1, 2), (1, 3), (2, 3)],
        };
        let order = p.topo_order().unwrap();
        let rank = |i: usize| order.iter().position(|&v| v == i).unwrap();
        assert!(rank(0) < rank(1));
        assert!(rank(1) < rank(2));
        assert!(rank(2) < rank(3));
    }

    #[test]
    fn hierarchical_has_two_train_tasks() {
        let p = PipelineTemplate::Hierarchical.build(Framework::TensorFlow);
        let trains = p.nodes.iter().filter(|n| n.task == TaskType::Train).count();
        assert_eq!(trains, 2);
    }
}
