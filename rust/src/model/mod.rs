//! Conceptual system model (paper section IV-A): pipelines, tasks, assets,
//! infrastructure resources, task executors, and the compression-effect
//! model calibrated on Table I.

pub mod asset;
pub mod compression;
pub mod executor;
pub mod infra;
pub mod pipeline;
pub mod task;

pub use asset::{DataAsset, ModelMetrics, TrainedModel};
pub use compression::CompressionModel;
pub use executor::{Op, TaskExecutor};
pub use infra::{
    ClusterFailureConfig, FailureModel, FaultModel, HwClass, HwClasses, InfraConfig, ResourceKind,
    StoreConfig, TaskFaultConfig,
};
pub use pipeline::{Pipeline, PipelineId, PipelineTemplate};
pub use task::{Framework, ModelType, PredictionType, TaskType};
