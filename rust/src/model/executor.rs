//! Task executors (paper section IV-A1d): a task is a sequence of system
//! operations Ω = {read(A), write(A), req(R), rel(R), exec(v, R)}.
//!
//! The executor for each task type produces the canonical op sequence;
//! the coordinator simulates each op's duration (queueing for `req`,
//! store bandwidth for `read`/`write`, statistical models for `exec`).

use super::asset::DataAsset;
use super::infra::ResourceKind;
use super::task::TaskType;

/// A system operation ω ∈ Ω.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Op {
    /// Request a slot on a compute resource (may queue).
    Req(ResourceKind),
    /// Read `bytes` from the data store.
    Read(f64),
    /// The type-specific execution on the acquired resource.
    Exec,
    /// Write `bytes` to the data store.
    Write(f64),
    /// Release the resource slot.
    Rel(ResourceKind),
}

/// Builds op sequences for task types.
pub struct TaskExecutor;

impl TaskExecutor {
    /// The canonical sequence: req → read → exec → write → rel.
    ///
    /// Payload sizes follow the asset: preprocessing reads and re-writes
    /// the data asset (D → D', the paper substitutes D for D'); training
    /// reads the data asset and writes the model; model-stage tasks read
    /// and write the model artifact.
    pub fn ops(task: TaskType, data: &DataAsset, model_bytes: f64) -> Vec<Op> {
        let r = ResourceKind::for_task(task);
        let (read_bytes, write_bytes) = match task {
            TaskType::Preprocess => (data.bytes, data.bytes),
            TaskType::Train => (data.bytes, model_bytes),
            TaskType::Evaluate => (data.bytes * 0.2 + model_bytes, 1e4),
            TaskType::Compress => (model_bytes, model_bytes * 0.5),
            TaskType::Harden => (data.bytes * 0.5 + model_bytes, model_bytes),
            TaskType::Deploy => (model_bytes, model_bytes),
        };
        vec![
            Op::Req(r),
            Op::Read(read_bytes),
            Op::Exec,
            Op::Write(write_bytes),
            Op::Rel(r),
        ]
    }

    /// Total bytes moved to/from the store by a task (traffic accounting).
    pub fn payload_bytes(task: TaskType, data: &DataAsset, model_bytes: f64) -> (f64, f64) {
        let ops = Self::ops(task, data, model_bytes);
        let mut read = 0.0;
        let mut write = 0.0;
        for op in ops {
            match op {
                Op::Read(b) => read += b,
                Op::Write(b) => write += b,
                _ => {}
            }
        }
        (read, write)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn asset() -> DataAsset {
        DataAsset::new(10_000.0, 20.0, 5e7)
    }

    #[test]
    fn sequence_shape() {
        let ops = TaskExecutor::ops(TaskType::Train, &asset(), 1e8);
        assert_eq!(ops.len(), 5);
        assert!(matches!(ops[0], Op::Req(ResourceKind::Training)));
        assert!(matches!(ops[1], Op::Read(_)));
        assert!(matches!(ops[2], Op::Exec));
        assert!(matches!(ops[3], Op::Write(_)));
        assert!(matches!(ops[4], Op::Rel(ResourceKind::Training)));
    }

    #[test]
    fn first_and_last_are_req_rel() {
        for t in TaskType::ALL {
            let ops = TaskExecutor::ops(t, &asset(), 1e8);
            assert!(matches!(ops.first(), Some(Op::Req(_))));
            assert!(matches!(ops.last(), Some(Op::Rel(_))));
        }
    }

    #[test]
    fn train_reads_data_writes_model() {
        let a = asset();
        let ops = TaskExecutor::ops(TaskType::Train, &a, 1e8);
        match (&ops[1], &ops[3]) {
            (Op::Read(r), Op::Write(w)) => {
                assert_eq!(*r, a.bytes);
                assert_eq!(*w, 1e8);
            }
            _ => panic!("unexpected ops"),
        }
    }

    #[test]
    fn payload_accounting() {
        let a = asset();
        let (r, w) = TaskExecutor::payload_bytes(TaskType::Preprocess, &a, 0.0);
        assert_eq!(r, a.bytes);
        assert_eq!(w, a.bytes);
    }

    #[test]
    fn req_rel_matched_resource() {
        for t in TaskType::ALL {
            let ops = TaskExecutor::ops(t, &asset(), 1e6);
            let req = ops.iter().find_map(|o| match o {
                Op::Req(r) => Some(*r),
                _ => None,
            });
            let rel = ops.iter().find_map(|o| match o {
                Op::Rel(r) => Some(*r),
                _ => None,
            });
            assert_eq!(req, rel);
        }
    }
}
