//! Integration tests: the full gen → fit → simulate → analyze loop, the
//! CLI binary, and paper-shape assertions (who wins, where the crossovers
//! fall) across the subsystems.

use std::process::Command;
use std::sync::Arc;

use pipesim::analytics::figures;
use pipesim::coordinator::{fit_params, ArrivalSpec, Experiment, ExperimentConfig, SimParams};
use pipesim::des::DAY;
use pipesim::empirical::{AnalyticsDb, GroundTruth};
use pipesim::model::Framework;
use pipesim::runtime::Runtime;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("pipesim_it_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn full_loop_gen_fit_simulate_analyze() {
    let db = GroundTruth::new(99).generate_weeks(4);
    let runtime = Runtime::load_default().map(Arc::new);
    let params = fit_params(&db, runtime.clone()).unwrap();

    let cfg = ExperimentConfig {
        name: "it-full".into(),
        seed: 4,
        horizon: 7.0 * DAY,
        arrival: ArrivalSpec::Profile,
        ..Default::default()
    };
    let r = Experiment::new(cfg, params).with_runtime(runtime).run().unwrap();
    assert!(r.arrived > 2000, "arrived {}", r.arrived);
    assert!(r.completed as f64 > 0.9 * r.arrived as f64);

    // Fig 12a shape: train strata near-diagonal Q-Q (the paper's best fit)
    let qq = figures::fig12a_qq(&db, &r, 50);
    let spark = qq.iter().find(|q| q.name == "train/sparkml").unwrap();
    assert!(spark.quantile_corr > 0.95, "{}", spark.verdict());
    let tf = qq.iter().find(|q| q.name == "train/tensorflow").unwrap();
    assert!(tf.quantile_corr > 0.95, "{}", tf.verdict());
    // preprocess is fit through a 3-parameter curve: decent but not perfect
    let pre = qq.iter().find(|q| q.name == "preprocess").unwrap();
    assert!(pre.quantile_corr > 0.80, "{}", pre.verdict());

    // Fig 12b: interarrival Q-Q under the realistic profile
    let ia = figures::fig12b_qq(&db, &r, "profile", 50).unwrap();
    assert!(ia.quantile_corr > 0.95, "{}", ia.verdict());
}

#[test]
fn persistence_roundtrip_through_files() {
    let dir = tmpdir("persist");
    let db = GroundTruth::new(7).generate_weeks(2);
    let db_path = dir.join("db.json");
    db.save(&db_path).unwrap();
    let db2 = AnalyticsDb::load(&db_path).unwrap();
    assert_eq!(db.jobs.len(), db2.jobs.len());
    assert_eq!(db.assets.len(), db2.assets.len());

    let params = fit_params(&db2, None).unwrap();
    let p_path = dir.join("params.json");
    params.save(&p_path).unwrap();
    let params2 = SimParams::load(&p_path).unwrap();
    assert!((params.preproc_curve.b - params2.preproc_curve.b).abs() < 1e-12);

    // identical seeds + params loaded from disk => identical runs
    let cfg = ExperimentConfig {
        name: "it-persist".into(),
        seed: 5,
        horizon: DAY,
        arrival: ArrivalSpec::Random,
        ..Default::default()
    };
    let a = Experiment::new(cfg.clone(), params).run().unwrap();
    let b = Experiment::new(cfg, params2).run().unwrap();
    assert_eq!(a.arrived, b.arrived);
    assert_eq!(a.events_processed, b.events_processed);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn framework_trend_saturates_training_cluster() {
    // paper section V-A2b: TF jobs are ~18x longer; raising the TF share
    // must raise training utilization monotonically (shape assertion)
    let db = GroundTruth::new(3).generate_weeks(3);
    let params = fit_params(&db, None).unwrap();
    let mut utils = Vec::new();
    for tf in [0.32, 0.6, 0.8] {
        let cfg = ExperimentConfig {
            name: format!("tf{tf}"),
            seed: 6,
            horizon: 3.0 * DAY,
            arrival: ArrivalSpec::Profile,
            synth: pipesim::synth::SynthConfig::default().with_tensorflow_share(tf),
            record_traces: false,
            ..Default::default()
        };
        let r = Experiment::new(cfg, params.clone()).run().unwrap();
        utils.push(r.util_training);
    }
    assert!(
        utils[0] < utils[1] && utils[1] < utils[2],
        "utilization not monotone in TF share: {utils:?}"
    );
}

#[test]
fn capacity_crossover_shape() {
    // Fig 11's story: scarce training capacity => queueing; ample => none.
    let db = GroundTruth::new(13).generate_weeks(3);
    let params = fit_params(&db, None).unwrap();
    let run = |cap: usize| {
        let mut cfg = ExperimentConfig {
            name: format!("cap{cap}"),
            seed: 8,
            horizon: 3.0 * DAY,
            arrival: ArrivalSpec::Profile,
            record_traces: false,
            ..Default::default()
        };
        cfg.infra.training_capacity = cap;
        Experiment::new(cfg, params.clone()).run().unwrap()
    };
    let scarce = run(2);
    let ample = run(32);
    assert!(scarce.wait_training.mean() > 10.0 * ample.wait_training.mean().max(0.1));
    assert!(scarce.util_training > ample.util_training);
    assert!(ample.completed >= scarce.completed);
}

#[test]
fn duration_medians_flow_through_simulation() {
    // end-to-end: empirical medians -> fit -> simulated exec durations
    let db = GroundTruth::new(23).generate_weeks(4);
    let params = fit_params(&db, None).unwrap();
    let cfg = ExperimentConfig {
        name: "medians".into(),
        seed: 9,
        horizon: 7.0 * DAY,
        arrival: ArrivalSpec::Random,
        ..Default::default()
    };
    let r = Experiment::new(cfg, params).run().unwrap();
    let spark = figures::simulated_durations(&r, "train", Some(Framework::SparkML.name()));
    let tf = figures::simulated_durations(&r, "train", Some(Framework::TensorFlow.name()));
    assert!(spark.len() > 200 && tf.len() > 100);
    let med = |xs: &[f64]| pipesim::stats::quantile(xs, 0.5);
    let (ms, mt) = (med(&spark), med(&tf));
    // paper: Spark p50 ~10 s, TF p50 ~180 s
    assert!((4.0..25.0).contains(&ms), "spark median {ms}");
    assert!((100.0..320.0).contains(&mt), "tf median {mt}");
    assert!(mt > 8.0 * ms);
}

// ------------------------------------------------------------------
// CLI binary smoke tests
// ------------------------------------------------------------------

fn pipesim_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_pipesim"))
}

#[test]
fn cli_end_to_end() {
    let dir = tmpdir("cli");
    let db = dir.join("db.json");
    let params = dir.join("params.json");

    let out = pipesim_bin()
        .args(["gen-empirical", "--weeks", "2", "--seed", "3"])
        .arg("--out")
        .arg(&db)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let out = pipesim_bin()
        .arg("fit")
        .arg("--db")
        .arg(&db)
        .arg("--out")
        .arg(&params)
        .arg("--cpu")
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(params.exists());

    let out = pipesim_bin()
        .arg("simulate")
        .arg("--params")
        .arg(&params)
        .args(["--days", "1", "--arrival", "poisson:120", "--cpu"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("dashboard"), "missing dashboard: {text}");
    assert!(text.contains("pipelines"));

    // parallel sweep over a small capacity x scheduler x seed grid —
    // operational strategies are a sweep axis like any other
    let cells = dir.join("cells.csv");
    let out = pipesim_bin()
        .arg("sweep")
        .arg("--params")
        .arg(&params)
        .args([
            "--days", "0.25", "--arrival", "poisson:120", "--seeds", "2", "--jobs", "2",
            "--capacities", "2,4", "--schedulers", "fifo,edf:slack_per_class=900", "--cpu",
            "--export",
        ])
        .arg(&cells)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("group 'default-cap2-fifo'"), "{text}");
    assert!(
        text.contains("group 'default-cap4-edf:slack_per_class=900'"),
        "{text}"
    );
    let csv = std::fs::read_to_string(&cells).unwrap();
    assert_eq!(csv.lines().count(), 9, "2 caps x 2 scheds x 2 seeds + header: {csv}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn cli_strategies_selectable_from_json_config_alone() {
    // new schedulers/triggers are usable with zero recompilation: a JSON
    // config names them and `simulate` just runs it
    let dir = tmpdir("strategy_json");
    let db = dir.join("db.json");
    let params = dir.join("params.json");
    let ok = |out: &std::process::Output| {
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    };
    ok(&pipesim_bin()
        .args(["gen-empirical", "--weeks", "2", "--seed", "3", "--out"])
        .arg(&db)
        .output()
        .unwrap());
    ok(&pipesim_bin()
        .arg("fit")
        .arg("--db")
        .arg(&db)
        .arg("--out")
        .arg(&params)
        .arg("--cpu")
        .output()
        .unwrap());

    let cfg_path = dir.join("cfg.json");
    std::fs::write(
        &cfg_path,
        r#"{
            "name": "json-strategies", "seed": 4, "horizon": 43200.0,
            "arrival": {"mode": "poisson", "mean_interarrival": 120.0},
            "interarrival_factor": 1.0,
            "infra": {
                "training_capacity": 3, "compute_capacity": 8,
                "scheduler": {"name": "weighted_fair",
                               "params": {"weight_power": 1.5}},
                "store": {"read_bw": 4e8, "write_bw": 2.5e8,
                           "latency": 0.05, "tcp_overhead": 1.06}
            },
            "synth": {
                "framework_shares": [0.63, 0.32, 0.03, 0.01, 0.01],
                "p_preprocess": 0.55, "p_evaluate": 0.7, "p_compress": 0.1,
                "p_harden": 0.05, "p_reevaluate": 0.8, "p_transfer": 0.05,
                "p_deploy": 0.8
            },
            "sample_interval": 600.0,
            "record_traces": false,
            "runtime_view": {
                "enabled": true,
                "detector_interval": 3600.0,
                "decay_per_day": 0.05,
                "sudden_drift_prob": 0.02,
                "sudden_drift_drop": 0.08,
                "trigger": {"name": "performance_floor", "params": {"floor": 0.75}},
                "max_models": 200
            }
        }"#,
    )
    .unwrap();
    let out = pipesim_bin()
        .arg("simulate")
        .arg("--params")
        .arg(&params)
        .arg("--config")
        .arg(&cfg_path)
        .arg("--cpu")
        .output()
        .unwrap();
    ok(&out);
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("json-strategies"), "{text}");

    // an unknown strategy in the same file must be rejected up front
    let bad = std::fs::read_to_string(&cfg_path)
        .unwrap()
        .replace("weighted_fair", "not_a_scheduler");
    std::fs::write(&cfg_path, bad).unwrap();
    let out = pipesim_bin()
        .arg("simulate")
        .arg("--params")
        .arg(&params)
        .arg("--config")
        .arg(&cfg_path)
        .arg("--cpu")
        .output()
        .unwrap();
    assert!(!out.status.success());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn cli_table1_matches_paper_calibration() {
    let out = pipesim_bin().arg("table1").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    // paper values present as the calibration columns
    assert!(text.contains("80.7"), "{text}");
    assert!(text.lines().count() >= 6);
}

#[test]
fn cli_rejects_unknown_subcommand_and_option() {
    let out = pipesim_bin().arg("bogus").output().unwrap();
    assert!(!out.status.success());
    let out = pipesim_bin()
        .args(["table1", "--bogus", "1"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}
