//! Observability-layer integration and property tests: downsampled
//! retention vs raw storage, sketch merge laws, meter/retention digest
//! neutrality, and the OpenMetrics/JSON exporters against real runs.

use pipesim::coordinator::{
    fit_params, ArrivalSpec, Experiment, ExperimentConfig, RetentionConfig,
};
use pipesim::empirical::GroundTruth;
use pipesim::obs::{render_metrics_json, render_openmetrics};
use pipesim::stats::rng::Pcg64;
use pipesim::stats::{FixedHistogram, TDigest};
use pipesim::tsdb::{SeriesKey, TsStore};
use pipesim::util::json::Json;

const CASES: u64 = 16;

fn exact_quantile(sorted: &[f64], q: f64) -> f64 {
    // nearest-rank is enough for the tolerance checks below
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

/// Downsampled windows must agree with the raw points they replaced:
/// count/sum/min/max/last are running aggregates over the identical
/// append sequence (bit-exact), and sketched quantiles stay within a
/// small fraction of the bucket's value range.
#[test]
fn prop_downsampled_windows_match_raw_aggregates() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(900 + seed);
        // dense series, coarse windows: ~1-2k points per bucket, so the
        // sketch (bounded centroids) is far smaller than the raw points
        let resolution = 500.0 + rng.uniform() * 500.0;
        let mut raw = TsStore::new();
        let mut rolled = TsStore::new();
        rolled.set_retention(resolution);
        let hr = raw.handle(SeriesKey::new("m").tag("k", "v"));
        let hd = rolled.handle(SeriesKey::new("m").tag("k", "v"));
        let n = 2000 + (seed as usize) * 500;
        let mut t = 0.0;
        let mut points: Vec<(f64, f64)> = Vec::with_capacity(n);
        for _ in 0..n {
            t += rng.uniform();
            // heavy-tailed values exercise the sketch across scales
            let v = (-(rng.uniform().max(1e-12)).ln()).powi(2) * 10.0;
            raw.append(hr, t, v);
            rolled.append(hd, t, v);
            points.push((t, v));
        }
        // observed counts agree even though residency differs
        assert_eq!(raw.num_points(), rolled.num_points(), "seed {seed}");
        assert!(rolled.resident_points() < raw.resident_points(), "seed {seed}");

        let ws = rolled.downsampled(hd).expect("retention is on");
        assert_eq!(ws.observed(), n as u64);
        let mut covered = 0u64;
        for b in ws.buckets() {
            let in_bucket: Vec<f64> = points
                .iter()
                .filter(|(pt, _)| *pt >= b.start && *pt < b.start + resolution)
                .map(|&(_, v)| v)
                .collect();
            assert_eq!(b.count, in_bucket.len() as u64, "seed {seed}");
            covered += b.count;
            // the bucket accumulated in the same order the reference
            // sums here, so even the f64 sum is bit-identical
            let sum: f64 = in_bucket.iter().fold(0.0, |a, v| a + v);
            assert_eq!(b.sum.to_bits(), sum.to_bits(), "seed {seed}");
            let min = in_bucket.iter().cloned().fold(f64::INFINITY, f64::min);
            let max = in_bucket.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            assert_eq!(b.min.to_bits(), min.to_bits(), "seed {seed}");
            assert_eq!(b.max.to_bits(), max.to_bits(), "seed {seed}");
            assert_eq!(b.last.to_bits(), in_bucket.last().unwrap().to_bits());
            // sketched quantiles: within 10% of the bucket's value range
            let mut sorted = in_bucket.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let range = (max - min).max(1e-9);
            for q in [0.5, 0.95] {
                let approx = b.sketch.quantile(q);
                let exact = exact_quantile(&sorted, q);
                assert!(
                    (approx - exact).abs() <= 0.10 * range,
                    "seed {seed} q{q}: sketch {approx} vs exact {exact} (range {range})"
                );
            }
        }
        assert_eq!(covered, n as u64, "seed {seed}: every point in a bucket");
        // the rolled store's footprint is a fraction of raw at this
        // point density (the acceptance bound the bench also guards)
        assert!(
            rolled.approx_bytes() < raw.approx_bytes() / 2,
            "seed {seed}: {} vs {}",
            rolled.approx_bytes(),
            raw.approx_bytes()
        );
    }
}

/// Merging sketches must commute/associate up to quantile accuracy:
/// any merge order gives exact count/min/max and quantiles within the
/// digest's error of the pooled exact quantile. The fixed-bin histogram
/// is exactly associative (bin counts are integers).
#[test]
fn prop_sketch_merge_is_order_insensitive() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(1700 + seed);
        let parts: Vec<Vec<f64>> = (0..3)
            .map(|_| {
                (0..500 + rng.below(1500))
                    .map(|_| rng.uniform() * 100.0 + (seed as f64))
                    .collect()
            })
            .collect();
        let digest_of = |xs: &[f64]| {
            let mut d = TDigest::new(100.0);
            for &x in xs {
                d.add(x);
            }
            d
        };
        let [a, b, c] = [
            digest_of(&parts[0]),
            digest_of(&parts[1]),
            digest_of(&parts[2]),
        ];
        // (a + b) + c
        let mut left = a.clone();
        left.merge_from(&b);
        left.merge_from(&c);
        // a + (b + c)
        let mut bc = b.clone();
        bc.merge_from(&c);
        let mut right = a.clone();
        right.merge_from(&bc);

        let mut all: Vec<f64> = parts.concat();
        all.sort_by(|x, y| x.partial_cmp(y).unwrap());
        for m in [&left, &right] {
            assert_eq!(m.count(), all.len() as u64, "seed {seed}");
            assert_eq!(m.min().to_bits(), all[0].to_bits(), "seed {seed}");
            assert_eq!(
                m.max().to_bits(),
                all[all.len() - 1].to_bits(),
                "seed {seed}"
            );
            let range = all[all.len() - 1] - all[0];
            for q in [0.1, 0.5, 0.9, 0.99] {
                let err = (m.quantile(q) - exact_quantile(&all, q)).abs();
                assert!(
                    err <= 0.05 * range,
                    "seed {seed} q{q}: err {err} of range {range}"
                );
            }
        }

        // fixed histograms with matching bins merge exactly associatively
        let hist_of = |xs: &[f64]| {
            let mut h = FixedHistogram::new(0.0, 200.0, 64);
            for &x in xs {
                h.add(x);
            }
            h
        };
        let [ha, hb, hc] = [
            hist_of(&parts[0]),
            hist_of(&parts[1]),
            hist_of(&parts[2]),
        ];
        let mut hl = ha.clone();
        assert!(hl.merge_from(&hb));
        assert!(hl.merge_from(&hc));
        let mut hbc = hb.clone();
        assert!(hbc.merge_from(&hc));
        let mut hr = ha.clone();
        assert!(hr.merge_from(&hbc));
        assert_eq!(hl.bin_counts(), hr.bin_counts(), "seed {seed}");
        assert_eq!(hl.count(), all.len() as u64, "seed {seed}");
    }
}

fn quick_cfg(name: &str) -> ExperimentConfig {
    ExperimentConfig {
        name: name.into(),
        seed: 5,
        horizon: 12.0 * 3600.0,
        arrival: ArrivalSpec::Poisson {
            mean_interarrival: 75.0,
        },
        sample_interval: 300.0,
        ..Default::default()
    }
}

/// The whole observability layer is a pure observer: turning the meter
/// on, retention on, or both must leave the digest byte-identical to
/// the plain run.
#[test]
fn meter_and_retention_are_digest_neutral() {
    let db = GroundTruth::new(77).generate_weeks(2);
    let params = fit_params(&db, None).unwrap();
    let run = |meter: bool, retention: Option<f64>| {
        let mut cfg = quick_cfg("obs-neutral");
        cfg.meter = meter;
        cfg.retention = retention.map(|resolution| RetentionConfig { resolution });
        Experiment::new(cfg, params.clone()).run().unwrap()
    };
    let plain = run(false, None);
    let metered = run(true, None);
    let rolled = run(false, Some(1800.0));
    let both = run(true, Some(1800.0));
    assert_eq!(plain.digest(), metered.digest());
    assert_eq!(plain.digest(), rolled.digest());
    assert_eq!(plain.digest(), both.digest());

    // the meter actually measured the run it rode along with
    assert!(plain.meter.is_none());
    let m = metered.meter.as_ref().unwrap();
    assert_eq!(m.total_events(), metered.events_processed);
    assert!(m.calendar_scheduled > 0);
    assert!(m.calendar_depth_hwm > 0);
    let arrivals = m
        .events_by_kind
        .iter()
        .find(|(k, _)| k == "arrival")
        .unwrap()
        .1;
    assert_eq!(arrivals, metered.arrived);
    assert!(m.rng_draws.iter().any(|(_, n)| *n > 0));

    // retention actually rolled points into windows
    assert!(rolled.tsdb.retention().is_some());
    assert!(rolled.tsdb.resident_points() < plain.tsdb.resident_points());
    assert!(rolled
        .tsdb
        .handles()
        .any(|h| rolled.tsdb.downsampled(h).is_some()));
    // ...while observing the same point count the digest covers
    assert_eq!(rolled.tsdb.num_points(), plain.tsdb.num_points());
}

/// The OpenMetrics export of a real metered run: structurally valid
/// (every line is a comment or `pipesim_name{...} value`, terminated by
/// `# EOF`) and covering all four metric families.
#[test]
fn openmetrics_export_covers_all_families_and_parses() {
    let db = GroundTruth::new(78).generate_weeks(2);
    let params = fit_params(&db, None).unwrap();
    let mut cfg = quick_cfg("obs-export");
    cfg.meter = true;
    cfg.retention = Some(RetentionConfig { resolution: 1800.0 });
    let r = Experiment::new(cfg, params).run().unwrap();

    let text = render_openmetrics(&r);
    assert!(text.ends_with("# EOF\n"));
    for line in text.lines() {
        if line == "# EOF" || line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            assert!(
                rest.starts_with("TYPE pipesim_") || rest.starts_with("HELP pipesim_"),
                "bad comment line: {line}"
            );
            continue;
        }
        // sample line: name{labels} value — value must parse as f64
        assert!(line.starts_with("pipesim_"), "bad sample line: {line}");
        let value = line.rsplit(' ').next().unwrap();
        assert!(value.parse::<f64>().is_ok(), "unparseable value: {line}");
    }
    // one representative per family: run outcome, reliability ledger,
    // recorded series, and the self-profiling meter
    for needle in [
        "pipesim_pipelines_arrived_total",
        "pipesim_goodput_ratio",
        "pipesim_series_count{",
        "pipesim_meter_events_total{kind=\"arrival\"}",
        "pipesim_meter_rng_draws_total{",
    ] {
        assert!(text.contains(needle), "missing {needle}");
    }
    // downsampled series still export quantiles (via the sketches)
    assert!(text.contains("pipesim_series_p95{"));

    // the JSON renderer carries the same sections
    let doc = Json::parse(&render_metrics_json(&r)).unwrap();
    assert_eq!(
        doc.req("outcome").unwrap().f("arrived").unwrap(),
        r.arrived as f64
    );
    assert!(!matches!(doc.req("meter").unwrap(), Json::Null));
    assert!(!doc.req("series").unwrap().as_arr().unwrap().is_empty());
}
