//! Property-based tests over randomized inputs (in-tree harness: seeds
//! drive a PCG64 stream; failures print the offending seed/case).
//!
//! Invariants covered (DESIGN.md section 5): event-clock monotonicity and
//! FIFO tie-breaks, resource capacity/conservation/grant order, pipeline
//! structural validity, experiment conservation laws, tsdb window
//! consistency, distribution fit round-trips, JSON round-trips.

use pipesim::coordinator::{
    build_scheduler, fit_params, placer_names, retry_policy_names, scheduler_names,
    trigger_names, ArrivalSpec, Experiment, ExperimentConfig, StrategySpec, Sweep,
};
use pipesim::des::sched::{default_grants, SchedView, WaiterView};
use pipesim::des::{AcquireResult, Calendar, JobCtx, Resource, SchedCtx, Scheduler};
use pipesim::empirical::GroundTruth;
use pipesim::model::{
    ClusterFailureConfig, FailureModel, FaultModel, HwClass, HwClasses, TaskFaultConfig,
};
use pipesim::stats::dist::{Dist, Distribution, ExpWeibull, LogNormal, Pareto, Weibull};
use pipesim::stats::rng::Pcg64;
use pipesim::synth::{PipelineSynthesizer, SynthConfig};
use pipesim::tsdb::{Agg, SeriesKey, TsStore};
use pipesim::util::json::Json;
use pipesim::util::jsonio::JsonIo;

const CASES: u64 = 24;

#[test]
fn prop_calendar_pops_sorted_under_random_schedules() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(seed);
        let mut cal: Calendar<u32> = Calendar::new();
        // random interleaving of schedules and pops
        let mut popped: Vec<f64> = Vec::new();
        let mut id = 0u32;
        for _ in 0..2000 {
            if rng.uniform() < 0.6 || cal.is_empty() {
                let delay = rng.uniform() * 1000.0;
                cal.schedule(delay, id);
                id += 1;
            } else {
                let (t, _) = cal.pop().unwrap();
                popped.push(t);
            }
        }
        while let Some((t, _)) = cal.pop() {
            popped.push(t);
        }
        for w in popped.windows(2) {
            assert!(w[0] <= w[1], "seed {seed}: out of order {w:?}");
        }
        assert_eq!(popped.len(), id as usize);
    }
}

#[test]
fn prop_cancelled_events_never_fire_and_pop_matches_reference_model() {
    // drive random interleavings of schedule / pop / cancel against a
    // naive sorted-Vec reference: the calendar's live-event pop sequence
    // and every cancel verdict must match the model exactly, and the
    // lazy-tombstone ratio must stay bounded by compaction
    for seed in 0..CASES {
        let mut rng = Pcg64::new(11_000 + seed);
        let mut cal: Calendar<u32> = Calendar::new();
        // reference: (time, seq, id, live) — pops take the (time, seq)
        // minimum among live entries
        let mut model: Vec<(f64, u64, u32, bool)> = Vec::new();
        let mut handles = Vec::new();
        let mut id = 0u32;
        let mut seq = 0u64;
        for _ in 0..3000 {
            let op = rng.uniform();
            if op < 0.5 || cal.is_empty() {
                let t = cal.now() + rng.uniform() * 1000.0;
                handles.push(cal.schedule_at(t, id));
                model.push((t, seq, id, true));
                seq += 1;
                id += 1;
            } else if op < 0.75 {
                // cancel a random handle (possibly fired or already
                // cancelled — verdicts must agree with the model)
                let pick = rng.below(handles.len());
                let got = cal.cancel(handles[pick]);
                let want = match model.iter_mut().find(|e| e.1 == pick as u64) {
                    Some(e) if e.3 => {
                        e.3 = false;
                        true
                    }
                    _ => false,
                };
                assert_eq!(got, want, "seed {seed}: cancel verdict diverged");
            } else {
                let got = cal.pop();
                // model pop: (time, seq)-min among live entries
                let best = model
                    .iter()
                    .enumerate()
                    .filter(|(_, e)| e.3)
                    .min_by(|(_, a), (_, b)| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
                    .map(|(i, _)| i);
                // fired and cancelled entries both leave the model
                model.retain(|e| e.3);
                match (got, best) {
                    (Some((t, v)), Some(_)) => {
                        let k = model
                            .iter()
                            .enumerate()
                            .min_by(|(_, a), (_, b)| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
                            .map(|(i, _)| i)
                            .unwrap();
                        let e = model.remove(k);
                        assert_eq!((t, v), (e.0, e.2), "seed {seed}: pop diverged");
                    }
                    (None, None) => {}
                    (g, b) => panic!("seed {seed}: emptiness diverged: {g:?} vs {b:?}"),
                }
            }
            // compaction invariant (cancel- and pop-side triggers):
            // tombstones never exceed max(backing/2, the 64-entry floor)
            assert!(
                cal.tombstones() <= (cal.backing_len() / 2).max(64),
                "seed {seed}: tombstone ratio unbounded ({}/{})",
                cal.tombstones(),
                cal.backing_len()
            );
            assert_eq!(
                cal.len(),
                model.iter().filter(|e| e.3).count(),
                "seed {seed}: live count diverged"
            );
        }
        // drain both to the end — cancelled events must never surface
        while let Some((t, v)) = cal.pop() {
            let k = model
                .iter()
                .enumerate()
                .filter(|(_, e)| e.3)
                .min_by(|(_, a), (_, b)| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
                .map(|(i, _)| i)
                .expect("model empty but calendar popped");
            let e = model.remove(k);
            assert_eq!((t, v), (e.0, e.2), "seed {seed}: drain diverged");
        }
        assert!(model.iter().all(|e| !e.3), "seed {seed}: live events lost");
    }
}

#[test]
fn prop_cancel_then_reschedule_preserves_heap_ordering() {
    // re-scheduling a cancelled event at a new time must slot it into
    // the global order exactly as a fresh event
    for seed in 0..CASES {
        let mut rng = Pcg64::new(12_000 + seed);
        let mut cal: Calendar<u32> = Calendar::new();
        let mut expect: Vec<(f64, u64, u32)> = Vec::new();
        let mut seq = 0u64;
        for id in 0..500u32 {
            let t = rng.uniform() * 1e6;
            let h = cal.schedule_at(t, id);
            seq += 1;
            if rng.uniform() < 0.4 {
                // move it: cancel + schedule at a fresh time
                assert!(cal.cancel(h));
                let t2 = rng.uniform() * 1e6;
                cal.schedule_at(t2, id);
                expect.push((t2, seq, id));
                seq += 1;
            } else {
                expect.push((t, seq - 1, id));
            }
        }
        expect.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        for (want_t, _, want_id) in expect {
            let (t, v) = cal.pop().expect("calendar drained early");
            assert_eq!((t, v), (want_t, want_id), "seed {seed}");
        }
        assert!(cal.pop().is_none());
    }
}

/// Event-driven mini-simulator over one `Resource`: jobs arrive at fixed
/// times, run exactly their expected occupancy, and completions release
/// their slots — the reference harness for comparing grant schedules
/// across scheduling strategies under mixed-width workloads.
fn drive_resource(
    scheduler: &str,
    capacity: usize,
    arrivals: &[(f64, f64, u32)], // (arrival time, occupancy, slots)
) -> Vec<(f64, u32)> {
    // (start time, token) in start order
    let mut res: Resource<u32> = Resource::with_scheduler(
        "h",
        capacity,
        build_scheduler(&StrategySpec::new(scheduler)).unwrap(),
    );
    let mut starts: Vec<(f64, u32)> = Vec::new();
    // pending completions: (done time, token, slots), popped in
    // (time, token) order via linear min-scan (tiny sizes)
    let mut running: Vec<(f64, u32, u32)> = Vec::new();
    let mut next_arrival = 0usize;
    loop {
        let arr_t = arrivals.get(next_arrival).map(|a| a.0);
        let done = running
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
            .map(|(i, e)| (i, e.0));
        // completions strictly before arrivals; ties completion-first
        let take_done = match (done, arr_t) {
            (Some((_, dt)), Some(at)) => dt <= at,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (None, None) => break,
        };
        if take_done {
            let (i, t) = done.unwrap();
            let (_, tok, slots) = running.remove(i);
            let mut out = Vec::new();
            res.release_all(t, &tok, slots, &mut out);
            for g in out {
                let (arrived, occ, sl) = arrivals[g.token as usize];
                debug_assert!(arrived <= t);
                starts.push((t, g.token));
                running.push((t + occ, g.token, sl));
            }
        } else {
            let i = next_arrival;
            next_arrival += 1;
            let (t, occ, slots) = arrivals[i];
            let job = JobCtx::new(occ, 5.0, t).with_slots(slots);
            match res.request(t, i as u32, job) {
                AcquireResult::Acquired => {
                    starts.push((t, i as u32));
                    running.push((t + occ, i as u32, slots));
                }
                AcquireResult::Queued => {}
                AcquireResult::Preempted { .. } => unreachable!(),
            }
        }
    }
    assert_eq!(starts.len(), arrivals.len(), "{scheduler}: jobs lost");
    starts
}

#[test]
fn prop_easy_backfill_never_delays_the_first_blocked_head() {
    // the EASY guarantee, checked against plain FIFO on random
    // mixed-width workloads: the two runs are grant-for-grant identical
    // until the first backfill, and the head being reserved at that
    // divergence starts at exactly the same time in both runs (with
    // faithful occupancy estimates a backfill never delays the
    // reservation). Later heads may legitimately shift — EASY only
    // reserves for the current head.
    let mut diverged = 0u32;
    for seed in 0..CASES {
        let mut rng = Pcg64::new(13_000 + seed);
        let capacity = 4;
        let mut t = 0.0;
        let arrivals: Vec<(f64, f64, u32)> = (0..120)
            .map(|_| {
                t += rng.uniform() * 18.0;
                let slots = if rng.uniform() < 0.3 {
                    2 + rng.below(2) as u32 // wide: 2 or 3 slots
                } else {
                    1
                };
                (t, 5.0 + rng.uniform() * 60.0, slots)
            })
            .collect();
        let fifo = drive_resource("fifo", capacity, &arrivals);
        let easy = drive_resource("easy_backfill", capacity, &arrivals);
        let Some(div) = (0..fifo.len()).find(|&i| fifo[i] != easy[i]) else {
            continue; // no backfill opportunity this seed
        };
        diverged += 1;
        // the reserved head at divergence: FIFO grants strictly in
        // arrival order, so its next start IS the head of the queue
        let head = fifo[div].1;
        let start_of = |runs: &[(f64, u32)], tok: u32| {
            runs.iter().find(|(_, v)| *v == tok).map(|(s, _)| *s).unwrap()
        };
        assert_eq!(
            start_of(&fifo, head),
            start_of(&easy, head),
            "seed {seed}: backfill delayed the reserved head {head}"
        );
        // sanity: every job starts in both runs at or after its arrival
        for (i, a) in arrivals.iter().enumerate() {
            assert!(start_of(&easy, i as u32) >= a.0 - 1e-9, "seed {seed}");
        }
    }
    assert!(
        diverged as u64 >= CASES / 4,
        "backfill should engage on a fair share of seeds, got {diverged}/{CASES}"
    );
}

/// Key-based registry schedulers (the indexed-heap grant fast path).
const KEY_SCHEDULERS: [&str; 5] = ["fifo", "priority", "sjf", "edf", "weighted_fair"];

#[test]
fn prop_indexed_heap_grants_match_linear_scan_reference() {
    // the tentpole oracle: for every key-based scheduler, drive a
    // Resource (whose grants come off the indexed waiter heap) next to
    // a mirror queue granted by `default_grants` — the retained linear
    // (key, seq) argmin scan — on random mixed-width workloads. Grant
    // order AND waited times must be byte-identical at every release,
    // and the heap's stale-entry ratio must stay inside the compaction
    // bound after every operation.
    for mode in KEY_SCHEDULERS {
        for seed in 0..CASES {
            let mut rng = Pcg64::new(15_000 + seed);
            let cap = 2 + rng.below(3); // 2..=4 slots
            let mut res: Resource<u32> = Resource::with_scheduler(
                "h",
                cap,
                build_scheduler(&StrategySpec::new(mode)).unwrap(),
            );
            // the mirror scheduler instance sees the identical ctx
            // sequence, so stateful keys (weighted_fair) match bitwise
            let mut mirror_sched = build_scheduler(&StrategySpec::new(mode)).unwrap();
            let mut waiters: Vec<WaiterView> = Vec::new();
            let mut tokens: Vec<u32> = Vec::new();
            let mut mseq = 0u64;
            let mut running: Vec<(u32, u32)> = Vec::new(); // (token, slots)
            let mut t = 0.0;
            for i in 0..1200u32 {
                t += rng.uniform() * 5.0;
                if rng.uniform() < 0.6 || running.is_empty() {
                    let occ = rng.uniform() * 100.0;
                    let pri = 1.0 + rng.below(10) as f64;
                    let slots = if rng.uniform() < 0.25 {
                        1 + rng.below(cap.min(3)) as u32 // up to cap-wide
                    } else {
                        1
                    };
                    let job = JobCtx::new(occ, pri, t).with_slots(slots);
                    let ctx = SchedCtx {
                        now: t,
                        job,
                        in_use: res.in_use(),
                        capacity: cap,
                        queued: res.queued(),
                    };
                    match res.request(t, i, job) {
                        AcquireResult::Acquired => running.push((i, slots)),
                        AcquireResult::Queued => {
                            let key = mirror_sched.queue_key(&ctx);
                            waiters.push(WaiterView {
                                job,
                                key,
                                enq_t: t,
                                seq: mseq,
                            });
                            tokens.push(i);
                            mseq += 1;
                        }
                        AcquireResult::Preempted { .. } => {
                            unreachable!("key-based schedulers never preempt")
                        }
                    }
                } else {
                    let vi = rng.below(running.len());
                    let (tok, slots) = running.remove(vi);
                    let mut out = Vec::new();
                    res.release_all(t, &tok, slots, &mut out);
                    // reference decision: linear scan over the mirror
                    let in_use: usize = running.iter().map(|r| r.1 as usize).sum();
                    let view = SchedView {
                        now: t,
                        free: cap - in_use,
                        capacity: cap,
                        waiters: &waiters,
                        running: &[],
                    };
                    let mut grants = Vec::new();
                    default_grants(&view, &mut grants);
                    let want: Vec<u32> = grants.iter().map(|&gi| tokens[gi]).collect();
                    let got: Vec<u32> = out.iter().map(|g| g.token).collect();
                    assert_eq!(
                        got, want,
                        "{mode} seed {seed}: heap diverged from the linear scan"
                    );
                    for (g, &gi) in out.iter().zip(grants.iter()) {
                        assert_eq!(
                            g.waited.to_bits(),
                            (t - waiters[gi].enq_t).to_bits(),
                            "{mode} seed {seed}: waited time diverged"
                        );
                    }
                    // remove granted mirror entries, highest index first
                    let mut del = grants;
                    del.sort_unstable_by(|a, b| b.cmp(a));
                    for gi in del {
                        running.push((tokens[gi], waiters[gi].job.slots));
                        waiters.swap_remove(gi);
                        tokens.swap_remove(gi);
                    }
                    let occupied: usize = running.iter().map(|r| r.1 as usize).sum();
                    assert_eq!(res.in_use(), occupied, "{mode} seed {seed}: in_use drift");
                    assert_eq!(res.queued(), waiters.len(), "{mode} seed {seed}");
                }
                assert!(
                    res.index_heap_stale() <= (res.index_heap_len() / 2).max(64),
                    "{mode} seed {seed}: stale {} of {} unbounded",
                    res.index_heap_stale(),
                    res.index_heap_len()
                );
            }
        }
    }
}

#[test]
fn prop_deep_queue_heap_drains_in_exact_reference_order() {
    // Q ≈ 10k waiters: the asymptotic regime the heap exists for. The
    // drain order must equal the (key, seq) sort of the legacy rule —
    // keys drawn with heavy ties so the seq tie-break is exercised at
    // depth.
    for mode in ["fifo", "priority", "sjf"] {
        for seed in 0..3u64 {
            let mut rng = Pcg64::new(16_000 + seed);
            let mut res: Resource<u32> = Resource::with_scheduler(
                "deep",
                1,
                build_scheduler(&StrategySpec::new(mode)).unwrap(),
            );
            res.request(0.0, u32::MAX, JobCtx::new(1.0, 1.0, 0.0));
            let mut expect: Vec<(f64, u64, u32)> = Vec::new();
            for i in 0..10_000u32 {
                let occ = (rng.below(32) as f64) + 0.5;
                let pri = 1.0 + rng.below(8) as f64;
                res.request(i as f64, i, JobCtx::new(occ, pri, i as f64));
                let key = match mode {
                    "fifo" => 0.0,
                    "priority" => pri,
                    _ => occ,
                };
                expect.push((key, i as u64, i));
            }
            expect.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            for (n, &(_, _, tok)) in expect.iter().enumerate() {
                let g = res.release(20_000.0 + n as f64).unwrap();
                assert_eq!(g.token, tok, "{mode} seed {seed}: grant {n} diverged");
            }
            assert_eq!(res.queued(), 0, "{mode} seed {seed}");
        }
    }
}

#[test]
fn prop_conservation_under_sustained_overload() {
    // arrival rate far above service capacity for the whole horizon:
    // the wait queue grows with sim time (the deep-queue regime the
    // indexed heap targets) and the conservation law must still hold
    // exactly at the horizon
    let db = GroundTruth::new(99).generate_weeks(2);
    let params = fit_params(&db, None).unwrap();
    for name in ["fifo", "priority", "weighted_fair"] {
        let mut cfg = ExperimentConfig {
            name: format!("overload-{name}"),
            seed: 11,
            horizon: 86_400.0,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 12.0,
            },
            record_traces: false,
            sample_interval: 1800.0,
            ..Default::default()
        };
        cfg.infra.training_capacity = 2;
        cfg.infra.compute_capacity = 4;
        cfg.infra.scheduler = StrategySpec::new(name);
        let r = Experiment::new(cfg, params.clone()).run().unwrap();
        assert_eq!(
            r.arrived,
            r.completed + r.in_flight,
            "{name} broke conservation under overload"
        );
        assert!(r.completed > 0, "{name} completed nothing");
        assert!(
            r.in_flight > 100,
            "{name}: overload never built a backlog ({} in flight)",
            r.in_flight
        );
        assert!(
            r.avg_queue_training > 10.0,
            "{name}: training queue never deepened ({})",
            r.avg_queue_training
        );
        assert!(r.util_training > 0.95, "{name}: not saturated");
    }
}

#[test]
fn prop_resource_capacity_never_exceeded() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(1000 + seed);
        let cap = 1 + rng.below(8);
        let mut res: Resource<u32> = Resource::new("p", cap);
        let mut t = 0.0;
        let mut in_flight = 0usize;
        let mut queued = 0usize;
        for i in 0..3000u32 {
            t += rng.uniform();
            if rng.uniform() < 0.55 {
                let k = rng.uniform();
                match res.request(t, i, JobCtx::new(k, k, t)) {
                    AcquireResult::Acquired => in_flight += 1,
                    AcquireResult::Queued => queued += 1,
                    AcquireResult::Preempted { .. } => unreachable!("fifo never preempts"),
                }
            } else if in_flight > 0 {
                match res.release(t) {
                    Some(_) => {
                        queued -= 1; // slot transferred to a waiter
                    }
                    None => in_flight -= 1,
                }
            }
            assert!(res.in_use() <= cap, "seed {seed}: capacity exceeded");
            assert_eq!(res.in_use(), in_flight, "seed {seed}: in-use drift");
            assert_eq!(res.queued(), queued, "seed {seed}: queue drift");
        }
    }
}

#[test]
fn prop_fifo_grant_order_is_request_order() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(2000 + seed);
        let mut res: Resource<u32> = Resource::new("p", 1);
        res.request(0.0, u32::MAX, JobCtx::new(0.0, 0.0, 0.0)); // occupy the slot
        let n = 2 + rng.below(50) as u32;
        for i in 0..n {
            let k = rng.uniform();
            res.request(i as f64, i, JobCtx::new(k, k, i as f64));
        }
        for i in 0..n {
            let g = res.release(100.0 + i as f64).unwrap();
            assert_eq!(g.token, i, "seed {seed}: FIFO violated");
        }
    }
}

#[test]
fn prop_trait_schedulers_match_legacy_discipline_oracle() {
    // the pre-trait Resource ordered waiters by (key, seq) with
    // key = 0 (fifo) | priority (priority) | expected occupancy (sjf).
    // The trait-based reimplementation must reproduce that grant order
    // *exactly* on arbitrary request/release sequences — this is the
    // guard behind the byte-identical-digest claim of the refactor.
    for mode in ["fifo", "priority", "sjf"] {
        for seed in 0..CASES {
            let mut rng = Pcg64::new(9000 + seed);
            let cap = 1 + rng.below(4);
            let mut res: Resource<u32> = Resource::with_scheduler(
                "t",
                cap,
                build_scheduler(&StrategySpec::new(mode)).unwrap(),
            );
            // oracle queue: (legacy key, enqueue seq, token)
            let mut oracle: Vec<(f64, u64, u32)> = Vec::new();
            let mut seq = 0u64;
            let mut in_use = 0usize;
            let mut t = 0.0;
            for i in 0..2000u32 {
                t += rng.uniform();
                if rng.uniform() < 0.55 {
                    let occ = rng.uniform() * 100.0;
                    let pri = 1.0 + rng.below(10) as f64;
                    match res.request(t, i, JobCtx::new(occ, pri, t)) {
                        AcquireResult::Preempted { .. } => {
                            unreachable!("key-based schedulers never preempt")
                        }
                        AcquireResult::Acquired => in_use += 1,
                        AcquireResult::Queued => {
                            let key = match mode {
                                "fifo" => 0.0,
                                "priority" => pri,
                                _ => occ,
                            };
                            oracle.push((key, seq, i));
                            seq += 1;
                        }
                    }
                } else if in_use > 0 {
                    match res.release(t) {
                        Some(g) => {
                            let (idx, _) = oracle
                                .iter()
                                .enumerate()
                                .min_by(|(_, a), (_, b)| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
                                .unwrap();
                            let (_, _, token) = oracle.remove(idx);
                            assert_eq!(
                                g.token, token,
                                "{mode} seed {seed}: grant order diverged from oracle"
                            );
                        }
                        None => {
                            in_use -= 1;
                            assert!(oracle.is_empty(), "{mode} seed {seed}");
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn prop_every_registered_strategy_conserves_and_is_deterministic() {
    // the conservation invariant (arrived == completed + in_flight) and
    // digest determinism must hold for every scheduler and trigger in
    // the registry, not just the defaults — new strategies cannot
    // regress the core laws
    let db = GroundTruth::new(66).generate_weeks(2);
    let params = fit_params(&db, None).unwrap();
    for name in scheduler_names() {
        let mut cfg = ExperimentConfig {
            name: format!("sched-{name}"),
            seed: 7,
            horizon: 21_600.0,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 45.0,
            },
            record_traces: false,
            sample_interval: 600.0,
            ..Default::default()
        };
        // saturate training so queueing (and thus the strategy) engages
        cfg.infra.training_capacity = 3;
        cfg.infra.scheduler = StrategySpec::new(&name);
        let a = Experiment::new(cfg.clone(), params.clone()).run().unwrap();
        let b = Experiment::new(cfg, params.clone()).run().unwrap();
        assert_eq!(a.digest(), b.digest(), "scheduler {name} nondeterministic");
        assert_eq!(
            a.arrived,
            a.completed + a.in_flight,
            "scheduler {name} broke conservation"
        );
        assert!(a.completed > 0, "scheduler {name} completed nothing");
    }
    for name in trigger_names() {
        let mut cfg = ExperimentConfig {
            name: format!("trig-{name}"),
            seed: 7,
            horizon: 2.0 * 86_400.0,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 400.0,
            },
            record_traces: false,
            sample_interval: 1800.0,
            ..Default::default()
        };
        cfg.runtime_view.enabled = true;
        cfg.runtime_view.detector_interval = 3600.0;
        cfg.runtime_view.decay_per_day = 0.05;
        cfg.runtime_view.trigger = StrategySpec::new(&name);
        let a = Experiment::new(cfg.clone(), params.clone()).run().unwrap();
        let b = Experiment::new(cfg, params.clone()).run().unwrap();
        assert_eq!(a.digest(), b.digest(), "trigger {name} nondeterministic");
        assert_eq!(
            a.arrived,
            a.completed + a.in_flight,
            "trigger {name} broke conservation"
        );
    }
}

#[test]
fn prop_every_registered_placer_conserves_and_is_deterministic() {
    // the conservation and determinism laws must hold for every placer
    // in the registry on a genuinely heterogeneous fleet — a placement
    // strategy can pick any class it likes, but it cannot lose pipelines
    // or make the event stream seed-dependent
    let db = GroundTruth::new(66).generate_weeks(2);
    let params = fit_params(&db, None).unwrap();
    for name in placer_names() {
        let mut cfg = ExperimentConfig {
            name: format!("place-{name}"),
            seed: 7,
            horizon: 21_600.0,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 45.0,
            },
            record_traces: false,
            sample_interval: 600.0,
            ..Default::default()
        };
        // saturate a fast-expensive + slow-cheap fleet so placement engages
        cfg.infra.training_capacity = 3;
        cfg.infra.hw_classes = Some(HwClasses {
            training: vec![
                HwClass::new("fast", 1).with_speed(2.0).with_cost(0.004),
                HwClass::new("slow", 2).with_cost(0.001),
            ],
            compute: Vec::new(),
            placer: StrategySpec::new(&name),
        });
        let a = Experiment::new(cfg.clone(), params.clone()).run().unwrap();
        let b = Experiment::new(cfg, params.clone()).run().unwrap();
        assert_eq!(a.digest(), b.digest(), "placer {name} nondeterministic");
        assert_eq!(
            a.arrived,
            a.completed + a.in_flight,
            "placer {name} broke conservation"
        );
        assert!(a.completed > 0, "placer {name} completed nothing");
        assert!(a.cost > 0.0, "placer {name} accrued no cost on priced classes");
        assert_eq!(a.placer, name, "resolved placer label mismatch");
    }
}

#[test]
fn prop_conservation_holds_under_sustained_failure_injection() {
    // slot failures cancel in-flight completions, requeue the victims,
    // and shrink capacity until repair — under that churn every
    // registered scheduler must still conserve pipelines exactly and
    // stay deterministic, and the reliability counters must be coherent
    let db = GroundTruth::new(66).generate_weeks(2);
    let params = fit_params(&db, None).unwrap();
    for name in scheduler_names() {
        let mut cfg = ExperimentConfig {
            name: format!("fail-{name}"),
            seed: 7,
            horizon: 21_600.0,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 45.0,
            },
            record_traces: false,
            sample_interval: 600.0,
            ..Default::default()
        };
        // saturate training so failures hit busy slots, then fail hard
        // (MTBF 20min, MTTR 5min) with checkpointing on
        cfg.infra.training_capacity = 3;
        cfg.infra.scheduler = StrategySpec::new(&name);
        cfg.infra.failures = Some(FailureModel {
            training: Some(
                ClusterFailureConfig::exponential(1200.0, 300.0).with_checkpointing(600.0, 30.0),
            ),
            compute: None,
        });
        let a = Experiment::new(cfg.clone(), params.clone()).run().unwrap();
        let b = Experiment::new(cfg, params.clone()).run().unwrap();
        assert_eq!(a.digest(), b.digest(), "{name} nondeterministic with failures");
        assert!(a.failures > 0, "{name}: 6h at 20min MTBF never failed");
        assert_eq!(
            a.arrived,
            a.completed + a.in_flight,
            "{name} broke conservation under failures"
        );
        assert!(a.completed > 0, "{name} completed nothing");
        assert!(a.lost_work >= 0.0 && a.goodput > 0.0 && a.goodput <= 1.0, "{name}");
        assert!(a.repairs <= a.failures, "{name}: more repairs than failures");
    }
}

#[test]
fn prop_infinite_mtbf_loses_no_work() {
    // a failure model whose MTBF can never land inside the horizon is
    // inert: zero failures, zero lost work, perfect goodput, and the
    // exact digest of a config with no failure model at all
    let db = GroundTruth::new(66).generate_weeks(2);
    let params = fit_params(&db, None).unwrap();
    let mk = |failures: Option<FailureModel>| {
        let mut cfg = ExperimentConfig {
            name: "inert".into(),
            seed: 7,
            horizon: 21_600.0,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 45.0,
            },
            record_traces: false,
            sample_interval: 600.0,
            ..Default::default()
        };
        cfg.infra.training_capacity = 3;
        cfg.infra.failures = failures;
        Experiment::new(cfg, params.clone()).run().unwrap()
    };
    let inert = mk(Some(FailureModel::uniform(
        ClusterFailureConfig::exponential(1e30, 60.0).with_checkpointing(600.0, 30.0),
    )));
    let none = mk(None);
    assert_eq!(inert.failures, 0);
    assert_eq!(inert.lost_work, 0.0);
    assert_eq!(inert.goodput, 1.0);
    assert_eq!(inert.digest(), none.digest());
}

/// Overloaded config with transient task faults and admission control
/// on both clusters; the four-way conservation law is the invariant.
fn faulty_overload_cfg(sched: &str, retry: StrategySpec) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        name: format!("fault-{sched}-{}", retry.label()),
        seed: 11,
        horizon: 21_600.0,
        arrival: ArrivalSpec::Poisson {
            mean_interarrival: 12.0,
        },
        record_traces: false,
        sample_interval: 1800.0,
        ..Default::default()
    };
    cfg.infra.training_capacity = 2;
    cfg.infra.compute_capacity = 4;
    cfg.infra.scheduler = StrategySpec::new(sched);
    let mut faults = FaultModel::uniform(TaskFaultConfig::transient(3600.0).with_queue_cap(16));
    faults.retry = retry;
    cfg.infra.faults = Some(faults);
    cfg
}

#[test]
fn prop_conservation_under_faults_for_every_scheduler_and_retry() {
    // transient faults, retries, and shedding under sustained overload:
    // every pipeline must end in exactly one terminal bucket, so
    // arrived == completed + abandoned + shed + in_flight holds for
    // every registered scheduler crossed with every retry policy
    let db = GroundTruth::new(66).generate_weeks(2);
    let params = fit_params(&db, None).unwrap();
    for sched in scheduler_names() {
        for retry in retry_policy_names() {
            let cfg = faulty_overload_cfg(&sched, StrategySpec::new(&retry));
            let r = Experiment::new(cfg, params.clone()).run().unwrap();
            assert_eq!(
                r.arrived,
                r.completed + r.abandoned + r.shed + r.in_flight,
                "{sched}/{retry} broke conservation under faults"
            );
            assert!(r.completed > 0, "{sched}/{retry} completed nothing");
            assert!(
                r.task_faults > 0,
                "{sched}/{retry}: 6h of saturated load at 1h MTTF never faulted"
            );
            assert!(
                r.retries > 0 || r.abandoned > 0,
                "{sched}/{retry}: every fault must be retried or abandoned"
            );
        }
    }
}

#[test]
fn prop_fault_runs_are_deterministic_for_every_retry_policy() {
    // run-twice digest equality with faults on: the fault RNG substream,
    // retry re-queues, and shedding must all be replayable functions of
    // (config, seed)
    let db = GroundTruth::new(66).generate_weeks(2);
    let params = fit_params(&db, None).unwrap();
    for retry in retry_policy_names() {
        let cfg = faulty_overload_cfg("priority", StrategySpec::new(&retry));
        let a = Experiment::new(cfg.clone(), params.clone()).run().unwrap();
        let b = Experiment::new(cfg, params.clone()).run().unwrap();
        assert_eq!(a.digest(), b.digest(), "{retry} nondeterministic with faults");
        assert_eq!(a.task_faults, b.task_faults, "{retry}");
        assert_eq!(a.retries, b.retries, "{retry}");
        assert_eq!(a.abandoned, b.abandoned, "{retry}");
        assert_eq!(a.shed, b.shed, "{retry}");
    }
}

#[test]
fn prop_unreachable_fault_rate_is_digest_inert() {
    // the task-fault analog of prop_infinite_mtbf_loses_no_work: a fault
    // model whose fault times can never land inside an attempt draws
    // from its dedicated substream but perturbs nothing — zero fault
    // counters and the exact digest of a config with no fault model;
    // an all-knobs-off config is equally inert
    let db = GroundTruth::new(66).generate_weeks(2);
    let params = fit_params(&db, None).unwrap();
    let mk = |faults: Option<FaultModel>| {
        let mut cfg = ExperimentConfig {
            name: "inert-fault".into(),
            seed: 7,
            horizon: 21_600.0,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 45.0,
            },
            record_traces: false,
            sample_interval: 600.0,
            ..Default::default()
        };
        cfg.infra.training_capacity = 3;
        cfg.infra.faults = faults;
        Experiment::new(cfg, params.clone()).run().unwrap()
    };
    let none = mk(None);
    let mut unreachable = FaultModel::uniform(TaskFaultConfig::transient(1e30));
    unreachable.retry = StrategySpec::new("exp_backoff");
    let gated = mk(Some(unreachable));
    assert_eq!(gated.task_faults, 0);
    assert_eq!(gated.task_timeouts, 0);
    assert_eq!(gated.retries, 0);
    assert_eq!(gated.abandoned, 0);
    assert_eq!(gated.shed, 0);
    assert_eq!(gated.wasted_work, 0.0);
    assert_eq!(none.digest(), gated.digest());
    let inert = mk(Some(FaultModel::uniform(TaskFaultConfig::default())));
    assert_eq!(none.digest(), inert.digest());
}

#[test]
fn prop_legacy_and_spec_config_forms_are_digest_identical() {
    // the legacy JSON encodings ("discipline": "sjf", {"policy": ...})
    // must select exactly the same strategies as the canonical spec
    // form — byte-identical outcome digests
    let db = GroundTruth::new(44).generate_weeks(2);
    let params = fit_params(&db, None).unwrap();
    let base = ExperimentConfig {
        name: "forms".into(),
        seed: 3,
        horizon: 21_600.0,
        arrival: ArrivalSpec::Poisson {
            mean_interarrival: 45.0,
        },
        record_traces: false,
        ..Default::default()
    };
    // swap the canonical scheduler node for the legacy string form in
    // the JSON tree, then re-parse
    let mut j = base.to_json();
    let Json::Obj(fields) = &mut j else {
        panic!("config serializes to an object")
    };
    let infra = fields
        .iter_mut()
        .find(|(k, _)| k == "infra")
        .map(|(_, v)| v)
        .unwrap();
    let Json::Obj(infra_fields) = infra else {
        panic!("infra serializes to an object")
    };
    infra_fields.retain(|(k, _)| k != "scheduler");
    infra_fields.push(("discipline".to_string(), Json::Str("sjf".into())));
    let legacy = ExperimentConfig::from_json_text(&j.to_string()).unwrap();
    assert_eq!(legacy.infra.scheduler, StrategySpec::new("sjf"));
    let mut spec = base;
    spec.infra.scheduler = StrategySpec::new("sjf");
    let a = Experiment::new(legacy, params.clone()).run().unwrap();
    let b = Experiment::new(spec, params).run().unwrap();
    assert_eq!(a.digest(), b.digest());
}

#[test]
fn prop_synthesized_pipelines_always_valid() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(3000 + seed);
        // random synthesis probabilities
        let cfg = SynthConfig {
            framework_shares: [0.2, 0.2, 0.2, 0.2, 0.2],
            p_preprocess: rng.uniform(),
            p_evaluate: rng.uniform(),
            p_compress: rng.uniform(),
            p_harden: rng.uniform(),
            p_reevaluate: rng.uniform(),
            p_transfer: rng.uniform(),
            p_deploy: rng.uniform(),
        };
        let mut synth = PipelineSynthesizer::new(cfg, rng.substream(1));
        for _ in 0..300 {
            let p = synth.generate();
            p.validate().unwrap_or_else(|e| {
                panic!("seed {seed}: invalid pipeline {} ({e})", p.signature())
            });
        }
    }
}

#[test]
fn prop_experiment_conservation_and_determinism() {
    let db = GroundTruth::new(77).generate_weeks(2);
    let params = fit_params(&db, None).unwrap();
    for seed in 0..6 {
        let cfg = ExperimentConfig {
            name: format!("prop-{seed}"),
            seed,
            horizon: 43_200.0,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 60.0,
            },
            record_traces: true,
            ..Default::default()
        };
        let a = Experiment::new(cfg.clone(), params.clone()).run().unwrap();
        let b = Experiment::new(cfg, params.clone()).run().unwrap();
        // determinism
        assert_eq!(a.arrived, b.arrived);
        assert_eq!(a.tasks_executed, b.tasks_executed);
        assert_eq!(a.events_processed, b.events_processed);
        // conservation: completions never exceed arrivals; arrival markers
        // match the counter
        assert!(a.completed <= a.arrived);
        let marks: usize = a
            .tsdb
            .find("arrivals")
            .iter()
            .map(|&h| a.tsdb.series(h).len())
            .sum();
        assert_eq!(marks as u64, a.arrived);
        // every completed pipeline logged exactly one completion marker
        let comps: usize = a
            .tsdb
            .find("completions")
            .iter()
            .map(|&h| a.tsdb.series(h).len())
            .sum();
        assert_eq!(comps as u64, a.completed);
    }
}

#[test]
fn prop_sweep_determinism_under_parallelism() {
    // the sweep engine's core invariant: for the same (config, seed)
    // grid, per-cell results are byte-identical whether the cells run on
    // 1 worker or 8 — scheduling order must never leak into outcomes
    let db = GroundTruth::new(88).generate_weeks(2);
    let params = std::sync::Arc::new(fit_params(&db, None).unwrap());
    let build = |jobs: usize| {
        let mut sweep = Sweep::new(params.clone()).jobs(jobs);
        for group in 0..4u64 {
            let mut cfg = ExperimentConfig {
                name: format!("grid-{group}"),
                horizon: 21_600.0,
                arrival: ArrivalSpec::Poisson {
                    mean_interarrival: 60.0 + 30.0 * group as f64,
                },
                // mix traced and untraced cells: both paths must be stable
                record_traces: group % 2 == 0,
                sample_interval: 600.0,
                ..Default::default()
            };
            cfg.infra.training_capacity = 2 + group as usize;
            sweep.add_replications(&cfg, 1000 * group, 3);
        }
        sweep.run().unwrap()
    };
    let serial = build(1);
    let wide = build(8);
    let odd = build(3);
    assert_eq!(
        serial.digests(),
        wide.digests(),
        "jobs=1 vs jobs=8 diverged"
    );
    assert_eq!(serial.digests(), odd.digests(), "jobs=1 vs jobs=3 diverged");
    // sanity: the grid actually exercised distinct outcomes per group
    let unique: std::collections::HashSet<_> = serial.digests().into_iter().collect();
    assert_eq!(unique.len(), serial.results.len());
}

#[test]
fn prop_tsdb_window_counts_partition_points() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(4000 + seed);
        let mut db = TsStore::new();
        let h = db.handle(SeriesKey::new("m"));
        let mut t = 0.0;
        let n = 500 + rng.below(2000);
        for _ in 0..n {
            t += rng.uniform() * 10.0;
            db.append(h, t, rng.normal());
        }
        let t1 = t + 1.0;
        let width = 1.0 + rng.uniform() * 50.0;
        let windows = db.window(h, 0.0, t1, width, Agg::Count);
        let total: f64 = windows.iter().filter_map(|w| w.value).sum();
        assert_eq!(total as usize, n, "seed {seed}: window counts lost points");
        // mean of means weighted by counts == global mean
        let means = db.window(h, 0.0, t1, width, Agg::Mean);
        let weighted: f64 = windows
            .iter()
            .zip(&means)
            .filter_map(|(c, m)| Some(c.value? * m.value?))
            .sum();
        let global = db.aggregate(h, Agg::Mean).unwrap();
        assert!(
            (weighted / n as f64 - global).abs() < 1e-9,
            "seed {seed}: window means inconsistent"
        );
    }
}

#[test]
fn prop_distribution_sample_fit_roundtrip() {
    // sample from a random family member, refit, compare quantiles
    for seed in 0..8 {
        let mut rng = Pcg64::new(5000 + seed);
        let truth: Dist = match seed % 3 {
            0 => Dist::LogNormal(LogNormal::new(
                rng.uniform_range(0.5, 3.0),
                rng.uniform_range(0.3, 1.2),
            )),
            1 => Dist::Weibull(Weibull::new(
                rng.uniform_range(0.8, 2.5),
                rng.uniform_range(5.0, 50.0),
            )),
            _ => Dist::Pareto(Pareto::new(
                rng.uniform_range(0.5, 3.0),
                rng.uniform_range(1.2, 3.0),
            )),
        };
        let xs: Vec<f64> = (0..20_000).map(|_| truth.sample(&mut rng)).collect();
        let (fit, _) = pipesim::stats::select_best_fit(&xs, 50).unwrap();
        for &p in &[0.25, 0.5, 0.75, 0.9] {
            let (qt, qf) = (truth.quantile(p), fit.quantile(p));
            assert!(
                (qt - qf).abs() / qt < 0.15,
                "seed {seed} {}: q{p} {qt} vs {qf} ({})",
                truth.name(),
                fit.name()
            );
        }
    }
}

#[test]
fn prop_expweibull_quantile_cdf_inverse() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(6000 + seed);
        let d = ExpWeibull::new(
            rng.uniform_range(0.3, 4.0),
            rng.uniform_range(0.4, 3.0),
            rng.uniform_range(1.0, 100.0),
        );
        for _ in 0..50 {
            let p = rng.uniform_range(0.001, 0.999);
            let x = d.quantile(p);
            assert!(
                (d.cdf(x) - p).abs() < 1e-8,
                "seed {seed}: roundtrip failed at p={p}"
            );
        }
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_json(rng: &mut Pcg64, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.uniform() < 0.5),
            2 => Json::Num((rng.normal() * 1e3 * 64.0).round() / 64.0),
            3 => Json::Str(format!("s{}-\"q\"\n", rng.next_u64())),
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for seed in 0..100 {
        let mut rng = Pcg64::new(7000 + seed);
        let v = random_json(&mut rng, 4);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(back, v, "seed {seed}");
    }
}

#[test]
fn prop_config_jsonio_roundtrip_random() {
    for seed in 0..CASES {
        let mut rng = Pcg64::new(8000 + seed);
        let mut cfg = ExperimentConfig::default();
        cfg.seed = rng.next_u64() >> 12;
        cfg.horizon = rng.uniform_range(1e3, 1e8);
        cfg.interarrival_factor = rng.uniform_range(0.1, 10.0);
        cfg.infra.training_capacity = 1 + rng.below(100);
        cfg.max_pipelines = if rng.uniform() < 0.5 {
            Some(rng.next_u64() >> 20)
        } else {
            None
        };
        let back = ExperimentConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(back.seed, cfg.seed);
        assert_eq!(back.max_pipelines, cfg.max_pipelines);
        assert!((back.horizon - cfg.horizon).abs() < 1e-6 * cfg.horizon);
        assert_eq!(back.infra.training_capacity, cfg.infra.training_capacity);
    }
}
