//! Trace subsystem integration tests: capture → binary export →
//! re-ingest → replay round-trips (the digest-equality guarantee), event
//! conservation against result counters, and the CLI surface
//! (`trace export|stats|replay`, `sweep --trace-dir`, binary params).

use std::process::Command;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use pipesim::coordinator::config::RuntimeViewConfig;
use pipesim::coordinator::{
    fit_params, ArrivalSpec, Experiment, ExperimentConfig, SimParams, StrategySpec,
};
use pipesim::des::DAY;
use pipesim::empirical::GroundTruth;
use pipesim::model::{ClusterFailureConfig, FailureModel, FaultModel, TaskFaultConfig};
use pipesim::trace::{StreamingPstSink, Trace, TraceEvent, TraceEventKind, TraceSink, TraceWorkload};

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("pipesim_tr_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn quick_params(seed: u64) -> SimParams {
    let db = GroundTruth::new(seed).generate_weeks(2);
    fit_params(&db, None).unwrap()
}

/// A runtime-view-enabled config: exercises retraining, deferred
/// launches, and the (fixed) monitor drained condition.
fn runtime_view_cfg() -> ExperimentConfig {
    ExperimentConfig {
        name: "trace-rt".into(),
        seed: 13,
        horizon: 3.0 * DAY,
        arrival: ArrivalSpec::Poisson {
            mean_interarrival: 400.0,
        },
        capture_trace: true,
        runtime_view: RuntimeViewConfig {
            enabled: true,
            detector_interval: 3600.0,
            decay_per_day: 0.05,
            sudden_drift_prob: 0.05,
            sudden_drift_drop: 0.1,
            trigger: StrategySpec::new("drift_threshold").with("threshold", 0.04),
            max_models: 200,
        },
        ..Default::default()
    }
}

#[test]
fn capture_replay_roundtrip_is_byte_identical() {
    let params = Arc::new(quick_params(51));
    let mut captured = Experiment::new(runtime_view_cfg(), params.clone())
        .run()
        .unwrap();
    assert!(captured.retrains_triggered > 0, "workload must retrain");
    let trace = captured.trace.take().expect("capture on");
    let bytes = trace.to_bytes();

    // binary round-trip is lossless
    let loaded = Trace::from_bytes(&bytes).unwrap();
    assert_eq!(loaded, trace);

    // replaying the re-ingested trace reproduces the digest exactly —
    // without re-capturing (replay_config turns capture off)
    let workload = TraceWorkload::from_trace(&loaded).unwrap();
    let replayed = workload.run(params.clone(), None).unwrap();
    assert_eq!(replayed.digest(), captured.digest());
    assert!(replayed.trace.is_none(), "replay must not re-capture by default");

    // re-enabling capture on the replay re-exports byte-identically
    // (the captured config already had interarrival_factor == 1)
    let mut cfg = workload.replay_config();
    cfg.capture_trace = true;
    let mut recaptured = Experiment::new(cfg, params)
        .with_arrival(workload.arrival_model())
        .run()
        .unwrap();
    let trace2 = recaptured.trace.take().expect("capture re-enabled");
    assert_eq!(trace2.to_bytes(), bytes);
}

#[test]
fn capture_replay_roundtrip_profile_arrivals() {
    // the stochastic 168-cluster profile is the hard case: replay must
    // not re-draw from it but feed the recorded gaps back verbatim
    let params = Arc::new(quick_params(52));
    let cfg = ExperimentConfig {
        name: "trace-profile".into(),
        seed: 4,
        horizon: DAY,
        arrival: ArrivalSpec::Profile,
        capture_trace: true,
        ..Default::default()
    };
    let mut captured = Experiment::new(cfg, params.clone()).run().unwrap();
    let trace = captured.trace.take().unwrap();
    let replayed = TraceWorkload::from_trace(&trace)
        .unwrap()
        .run(params, None)
        .unwrap();
    assert_eq!(replayed.digest(), captured.digest());
    assert_eq!(replayed.arrived, captured.arrived);
}

#[test]
fn capture_flag_never_changes_outcomes() {
    // tracing is a pure observer: digests with capture on and off match
    let params = Arc::new(quick_params(53));
    let mut on = runtime_view_cfg();
    on.name = "flag".into();
    let mut off = on.clone();
    off.capture_trace = false;
    let a = Experiment::new(on, params.clone()).run().unwrap();
    let b = Experiment::new(off, params).run().unwrap();
    assert_eq!(a.digest(), b.digest());
    assert!(a.trace.is_some());
    assert!(b.trace.is_none());
}

#[test]
fn trace_events_conserve_result_counters() {
    let params = Arc::new(quick_params(54));
    let mut r = Experiment::new(runtime_view_cfg(), params).run().unwrap();
    let trace = r.trace.take().unwrap();
    let mut arrivals = 0u64;
    let mut done = 0u64;
    let mut gates = 0u64;
    let mut tasks = 0u64;
    let mut started = 0u64;
    let mut launches = 0u64;
    let mut gaps = 0u64;
    for ev in &trace.events {
        match ev.kind {
            TraceEventKind::PipelineArrival { .. } => arrivals += 1,
            TraceEventKind::PipelineDone { truncated, .. } => {
                done += 1;
                if truncated {
                    gates += 1;
                }
            }
            TraceEventKind::TaskStarted { .. } => started += 1,
            TraceEventKind::TaskDone { .. } => tasks += 1,
            TraceEventKind::RetrainLaunched { .. } => launches += 1,
            TraceEventKind::ArrivalGapDrawn { .. } => gaps += 1,
            _ => {}
        }
    }
    assert_eq!(arrivals, r.arrived);
    assert_eq!(done, r.completed);
    assert_eq!(gates, r.gate_failures);
    assert_eq!(tasks, r.tasks_executed);
    assert_eq!(launches, r.retrains_triggered);
    // every executed task has exactly one TaskStarted (immediate or
    // post-grant); the surplus is tasks still running at the horizon
    assert!(started >= tasks, "started {started} < done {tasks}");
    assert!(started - tasks <= 30, "more running tasks than slots");
    // one gap per *user* arrival plus the priming draw (retrain launches
    // inject pipelines without drawing gaps)
    assert_eq!(gaps, r.arrived - r.retrains_triggered + 1);
    // timestamps are non-decreasing in emission order
    assert!(trace.events.windows(2).all(|w| w[0].t <= w[1].t));
    // meta is self-describing
    assert_eq!(trace.meta.get("scheduler"), Some("fifo"));
    assert_eq!(
        trace.meta.get("trigger"),
        Some("drift_threshold:threshold=0.04")
    );
}

/// A saturated mixed-class workload under the preemptive scheduler.
fn preemptive_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        name: "trace-preempt".into(),
        seed: 21,
        horizon: DAY / 2.0,
        arrival: ArrivalSpec::Poisson {
            mean_interarrival: 25.0,
        },
        record_traces: false,
        ..Default::default()
    };
    cfg.infra.training_capacity = 2;
    cfg.infra.scheduler = StrategySpec::new("preemptive_priority");
    cfg
}

/// Counting sink shared with the test through atomics: proves the
/// `Experiment::with_sink` injection seam sees the full event stream
/// without buffering it (drain returns nothing — streaming-style).
#[derive(Default)]
struct CountingSink {
    total: Arc<AtomicU64>,
    preempted: Arc<AtomicU64>,
    requeued: Arc<AtomicU64>,
}

impl TraceSink for CountingSink {
    fn record(&mut self, ev: &TraceEvent) {
        self.total.fetch_add(1, Ordering::Relaxed);
        match ev.kind {
            TraceEventKind::TaskPreempted { .. } => {
                self.preempted.fetch_add(1, Ordering::Relaxed);
            }
            TraceEventKind::TaskRequeued { .. } => {
                self.requeued.fetch_add(1, Ordering::Relaxed);
            }
            _ => {}
        }
    }
}

#[test]
fn injected_sink_sees_preemption_events_without_buffering() {
    let params = Arc::new(quick_params(56));
    let sink = CountingSink::default();
    let (total, preempted, requeued) = (
        sink.total.clone(),
        sink.preempted.clone(),
        sink.requeued.clone(),
    );
    // capture_trace stays OFF: the injected sink alone turns capture on
    let cfg = preemptive_cfg();
    assert!(!cfg.capture_trace);
    let r = Experiment::new(cfg, params.clone())
        .with_sink(Box::new(sink))
        .run()
        .unwrap();
    assert!(r.preemptions > 0, "workload must preempt");
    assert_eq!(preempted.load(Ordering::Relaxed), r.preemptions);
    assert_eq!(requeued.load(Ordering::Relaxed), r.preemptions);
    assert!(total.load(Ordering::Relaxed) > 1000, "full stream reaches the sink");
    // streaming sinks drain empty: the result carries meta but no events
    assert!(r.trace.as_ref().is_some_and(|t| t.is_empty()));
    // the injected sink is a pure observer: outcome digest unchanged
    let plain = Experiment::new(preemptive_cfg(), params).run().unwrap();
    assert_eq!(r.digest(), plain.digest());
}

#[test]
fn streamed_capture_decodes_identical_to_memory_capture() {
    // the streaming acceptance bar: a StreamingPstSink run and a
    // MemorySink run of the same (config, seed) must be outcome-digest
    // equal, and the streamed .pst must re-read to the exact events and
    // metadata the in-memory capture produced — so the two capture
    // paths are interchangeable artifacts
    let dir = tmpdir("stream");
    let path = dir.join("streamed.pst");
    let params = Arc::new(quick_params(58));
    let cfg = runtime_view_cfg();
    assert!(cfg.capture_trace, "memory path captures via the flag");
    let mut buffered = Experiment::new(cfg.clone(), params.clone()).run().unwrap();
    let trace = buffered.trace.take().expect("capture on");
    assert!(trace.len() > 1000, "workload too small to prove anything");

    let sink = StreamingPstSink::create(&path, &cfg.trace_meta()).unwrap();
    let streamed = Experiment::new(cfg, params.clone())
        .with_sink(Box::new(sink))
        .run()
        .unwrap();
    assert_eq!(streamed.digest(), buffered.digest(), "capture is an observer");
    // the streaming sink drains empty: meta only on the result
    assert!(streamed.trace.as_ref().is_some_and(|t| t.is_empty()));

    let loaded = Trace::load(&path).unwrap();
    assert_eq!(loaded.meta, trace.meta, "metadata built by one constructor");
    assert_eq!(loaded.events.len(), trace.events.len());
    assert_eq!(loaded.events, trace.events, "streamed events diverged");
    // a streamed file is a runnable workload like any capture: replay
    // reproduces the original digest byte-for-byte
    let replayed = TraceWorkload::from_trace(&loaded)
        .unwrap()
        .run(params, None)
        .unwrap();
    assert_eq!(replayed.digest(), buffered.digest());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn streamed_replay_digest_equals_buffered_replay() {
    // `TraceWorkload::from_file` drives the replay record-by-record
    // through `TraceScanner` without ever materializing the event
    // vector; it must reproduce the buffered `Trace::load` → `from_trace`
    // digest exactly — for both the buffered and the streamed (footer)
    // file layouts
    let dir = tmpdir("stream_replay");
    let params = Arc::new(quick_params(59));
    let cfg = runtime_view_cfg();
    let mut captured = Experiment::new(cfg.clone(), params.clone()).run().unwrap();
    let trace = captured.trace.take().expect("capture on");

    // buffered layout: a whole-trace save
    let buffered_path = dir.join("buffered.pst");
    trace.save(&buffered_path).unwrap();
    // streamed layout: events written live, meta in the footer
    let streamed_path = dir.join("streamed.pst");
    let sink = StreamingPstSink::create(&streamed_path, &cfg.trace_meta()).unwrap();
    Experiment::new(cfg, params.clone())
        .with_sink(Box::new(sink))
        .run()
        .unwrap();

    let oracle = TraceWorkload::from_trace(&Trace::load(&buffered_path).unwrap())
        .unwrap()
        .run(params.clone(), None)
        .unwrap();
    assert_eq!(oracle.digest(), captured.digest());
    for path in [&buffered_path, &streamed_path] {
        let streamed = TraceWorkload::from_file(path)
            .unwrap()
            .run(params.clone(), None)
            .unwrap();
        assert_eq!(streamed.digest(), oracle.digest(), "{}", path.display());
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn preemptive_capture_replays_byte_identically_and_roundtrips_codec() {
    let params = Arc::new(quick_params(57));
    let mut cfg = preemptive_cfg();
    cfg.capture_trace = true;
    let mut captured = Experiment::new(cfg, params.clone()).run().unwrap();
    assert!(captured.preemptions > 0, "workload must preempt");
    let trace = captured.trace.take().unwrap();
    let preempt_events = trace
        .events
        .iter()
        .filter(|e| matches!(e.kind, TraceEventKind::TaskPreempted { .. }))
        .count() as u64;
    assert_eq!(preempt_events, captured.preemptions);

    // the new event kinds survive the binary codec bit-exactly
    let bytes = trace.to_bytes();
    let loaded = Trace::from_bytes(&bytes).unwrap();
    assert_eq!(loaded, trace);
    // encoding is deterministic and stamps the preemption-aware version
    assert_eq!(trace.to_bytes(), bytes);
    assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), 2);

    // replaying the re-ingested trace reproduces the digest exactly —
    // preemption decisions re-derive deterministically from the seed
    let replayed = TraceWorkload::from_trace(&loaded)
        .unwrap()
        .run(params, None)
        .unwrap();
    assert_eq!(replayed.digest(), captured.digest());
    assert_eq!(replayed.preemptions, captured.preemptions);
}

/// A saturated workload with slot failures, checkpointing, and restarts
/// on the training cluster.
fn failing_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        name: "trace-fail".into(),
        seed: 31,
        horizon: DAY / 2.0,
        arrival: ArrivalSpec::Poisson {
            mean_interarrival: 25.0,
        },
        record_traces: false,
        ..Default::default()
    };
    cfg.infra.training_capacity = 2;
    cfg.infra.failures = Some(FailureModel {
        training: Some(
            ClusterFailureConfig::exponential(1800.0, 300.0).with_checkpointing(600.0, 30.0),
        ),
        compute: None,
    });
    cfg
}

#[test]
fn failure_capture_replays_byte_identically_and_stamps_v4() {
    let params = Arc::new(quick_params(59));
    let mut cfg = failing_cfg();
    cfg.capture_trace = true;
    let mut captured = Experiment::new(cfg, params.clone()).run().unwrap();
    assert!(captured.failures > 0, "workload must fail");
    assert!(captured.lost_work > 0.0, "saturated slots must lose work");
    let trace = captured.trace.take().unwrap();

    // the failure records mirror the reliability counters exactly
    let count = |pred: fn(&TraceEventKind) -> bool| {
        trace.events.iter().filter(|e| pred(&e.kind)).count() as u64
    };
    let failed = count(|k| matches!(k, TraceEventKind::SlotFailed { .. }));
    let repaired = count(|k| matches!(k, TraceEventKind::SlotRepaired { .. }));
    let checkpointed = count(|k| matches!(k, TraceEventKind::TaskCheckpointed { .. }));
    let restarted = count(|k| matches!(k, TraceEventKind::TaskRestarted { .. }));
    assert_eq!(failed, captured.failures);
    assert_eq!(repaired, captured.repairs);
    assert_eq!(checkpointed, restarted, "each interruption restarts once");
    assert!(restarted > 0 && restarted <= failed);

    // failure records force the v4 stamp (buffered ⇒ reserved word 0);
    // the codec round-trips the new kinds bit-exactly
    let bytes = trace.to_bytes();
    assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), 4);
    assert_eq!(u16::from_le_bytes([bytes[6], bytes[7]]), 0);
    let loaded = Trace::from_bytes(&bytes).unwrap();
    assert_eq!(loaded, trace);
    assert_eq!(loaded.to_bytes(), bytes);

    // replay re-derives the failure stream from the recorded config and
    // seed: digest and reliability outcomes reproduce exactly
    let replayed = TraceWorkload::from_trace(&loaded)
        .unwrap()
        .run(params, None)
        .unwrap();
    assert_eq!(replayed.digest(), captured.digest());
    assert_eq!(replayed.failures, captured.failures);
    assert_eq!(replayed.repairs, captured.repairs);
    assert_eq!(replayed.lost_work.to_bits(), captured.lost_work.to_bits());
}

#[test]
fn streamed_failure_capture_patches_header_and_matches_memory() {
    // a StreamingPstSink cannot know mid-run whether a failure record
    // will appear; the close-time header patch must leave a valid v4
    // streamed file equal to the buffered capture
    let dir = tmpdir("failstream");
    let path = dir.join("fail.pst");
    let params = Arc::new(quick_params(60));
    let mut cfg = failing_cfg();
    cfg.capture_trace = true;
    let mut buffered = Experiment::new(cfg.clone(), params.clone()).run().unwrap();
    assert!(buffered.failures > 0, "workload must fail");
    let trace = buffered.trace.take().unwrap();

    let sink = StreamingPstSink::create(&path, &cfg.trace_meta()).unwrap();
    let streamed = Experiment::new(cfg, params)
        .with_sink(Box::new(sink))
        .run()
        .unwrap();
    assert_eq!(streamed.digest(), buffered.digest());
    let bytes = std::fs::read(&path).unwrap();
    assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), 4);
    assert_eq!(u16::from_le_bytes([bytes[6], bytes[7]]), 1, "streamed flag");
    let loaded = Trace::load(&path).unwrap();
    assert_eq!(loaded.meta, trace.meta);
    assert_eq!(loaded.events, trace.events, "streamed events diverged");
    std::fs::remove_dir_all(dir).ok();
}

/// A saturated workload with transient task faults, per-attempt
/// timeouts, admission-control shedding, and exponential-backoff
/// retries on both clusters.
fn faulty_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        name: "trace-fault".into(),
        seed: 33,
        horizon: DAY / 2.0,
        arrival: ArrivalSpec::Poisson {
            mean_interarrival: 20.0,
        },
        record_traces: false,
        ..Default::default()
    };
    cfg.infra.training_capacity = 2;
    let mut faults = FaultModel::uniform(
        TaskFaultConfig::transient(1200.0)
            .with_timeout(2400.0)
            .with_queue_cap(12),
    );
    faults.retry = StrategySpec::new("exp_backoff").with("base", 30.0);
    cfg.infra.faults = Some(faults);
    cfg
}

#[test]
fn fault_capture_replays_byte_identically_and_stamps_v6() {
    let params = Arc::new(quick_params(61));
    let mut cfg = faulty_cfg();
    cfg.capture_trace = true;
    let mut captured = Experiment::new(cfg, params.clone()).run().unwrap();
    assert!(captured.task_faults > 0, "workload must fault");
    assert!(captured.retries > 0, "faults must route through the policy");
    let trace = captured.trace.take().unwrap();

    // the fault records mirror the reliability counters exactly
    let count = |pred: fn(&TraceEventKind) -> bool| {
        trace.events.iter().filter(|e| pred(&e.kind)).count() as u64
    };
    assert_eq!(
        count(|k| matches!(k, TraceEventKind::TaskFailed { .. })),
        captured.task_faults
    );
    assert_eq!(
        count(|k| matches!(k, TraceEventKind::TaskRetried { .. })),
        captured.retries
    );
    assert_eq!(
        count(|k| matches!(k, TraceEventKind::TaskTimedOut { .. })),
        captured.task_timeouts
    );
    assert_eq!(
        count(|k| matches!(k, TraceEventKind::TaskShed { .. })),
        captured.shed
    );
    assert_eq!(
        count(|k| matches!(k, TraceEventKind::PipelineAbandoned { .. })),
        captured.abandoned
    );

    // fault records force the v6 stamp (buffered ⇒ reserved word 0);
    // the codec round-trips the new kinds bit-exactly
    let bytes = trace.to_bytes();
    assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), 6);
    assert_eq!(u16::from_le_bytes([bytes[6], bytes[7]]), 0);
    let loaded = Trace::from_bytes(&bytes).unwrap();
    assert_eq!(loaded, trace);
    assert_eq!(loaded.to_bytes(), bytes);

    // replay re-derives faults, backoff delays, timeouts, and sheds from
    // the recorded config and seed: digest and counters reproduce exactly
    let replayed = TraceWorkload::from_trace(&loaded)
        .unwrap()
        .run(params, None)
        .unwrap();
    assert_eq!(replayed.digest(), captured.digest());
    assert_eq!(replayed.task_faults, captured.task_faults);
    assert_eq!(replayed.retries, captured.retries);
    assert_eq!(replayed.task_timeouts, captured.task_timeouts);
    assert_eq!(replayed.shed, captured.shed);
    assert_eq!(replayed.abandoned, captured.abandoned);
    assert_eq!(replayed.wasted_work.to_bits(), captured.wasted_work.to_bits());
}

#[test]
fn streamed_fault_capture_patches_header_and_matches_memory() {
    // a StreamingPstSink cannot know mid-run whether a fault record
    // will appear; the close-time header patch must leave a valid v6
    // streamed file equal to the buffered capture
    let dir = tmpdir("faultstream");
    let path = dir.join("fault.pst");
    let params = Arc::new(quick_params(62));
    let mut cfg = faulty_cfg();
    cfg.capture_trace = true;
    let mut buffered = Experiment::new(cfg.clone(), params.clone()).run().unwrap();
    assert!(buffered.task_faults > 0, "workload must fault");
    let trace = buffered.trace.take().unwrap();

    let sink = StreamingPstSink::create(&path, &cfg.trace_meta()).unwrap();
    let streamed = Experiment::new(cfg, params)
        .with_sink(Box::new(sink))
        .run()
        .unwrap();
    assert_eq!(streamed.digest(), buffered.digest());
    let bytes = std::fs::read(&path).unwrap();
    assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), 6);
    assert_eq!(u16::from_le_bytes([bytes[6], bytes[7]]), 1, "streamed flag");
    let loaded = Trace::load(&path).unwrap();
    assert_eq!(loaded.meta, trace.meta);
    assert!(loaded.meta.get("retry").is_some(), "meta names the policy");
    assert_eq!(loaded.events, trace.events, "streamed events diverged");
    std::fs::remove_dir_all(dir).ok();
}

// ------------------------------------------------------------------
// CLI surface
// ------------------------------------------------------------------

fn pipesim_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_pipesim"))
}

fn ok(out: &std::process::Output) -> String {
    assert!(
        out.status.success(),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn digest_line(stdout: &str) -> String {
    stdout
        .lines()
        .find_map(|l| l.strip_prefix("digest: "))
        .unwrap_or_else(|| panic!("no digest line in: {stdout}"))
        .to_string()
}

#[test]
fn cli_trace_export_stats_replay() {
    let dir = tmpdir("cli");
    let db = dir.join("db.json");
    // binary params cache end-to-end: fit writes .bin, everything else
    // auto-detects it
    let params = dir.join("params.bin");
    ok(&pipesim_bin()
        .args(["gen-empirical", "--weeks", "2", "--seed", "3", "--out"])
        .arg(&db)
        .output()
        .unwrap());
    ok(&pipesim_bin()
        .arg("fit")
        .arg("--db")
        .arg(&db)
        .arg("--out")
        .arg(&params)
        .arg("--cpu")
        .output()
        .unwrap());
    assert!(pipesim::coordinator::params_bin::is_binary(
        &std::fs::read(&params).unwrap()
    ));

    let trace_file = dir.join("run.pst");
    let jsonl = dir.join("run.jsonl");
    let out = ok(&pipesim_bin()
        .args(["trace", "export", "--days", "0.5", "--arrival", "poisson:120", "--cpu"])
        .arg("--params")
        .arg(&params)
        .arg("--out")
        .arg(&trace_file)
        .arg("--jsonl")
        .arg(&jsonl)
        .output()
        .unwrap());
    let exported_digest = digest_line(&out);
    assert!(trace_file.exists());
    let jsonl_text = std::fs::read_to_string(&jsonl).unwrap();
    assert!(jsonl_text.lines().count() > 100, "jsonl too small");

    let out = ok(&pipesim_bin()
        .args(["trace", "stats", "--in"])
        .arg(&trace_file)
        .arg("--params")
        .arg(&params)
        .output()
        .unwrap());
    assert!(out.contains("pipelines"), "{out}");
    assert!(out.contains("interarrival/fit"), "{out}");

    let out = ok(&pipesim_bin()
        .args(["trace", "replay", "--cpu", "--in"])
        .arg(&trace_file)
        .arg("--params")
        .arg(&params)
        .output()
        .unwrap());
    assert_eq!(digest_line(&out), exported_digest, "CLI replay diverged");

    // unknown action fails fast
    let out = pipesim_bin()
        .args(["trace", "bogus"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn cli_sweep_trace_dir_dumps_one_trace_per_cell() {
    let dir = tmpdir("sweepdir");
    let db = dir.join("db.json");
    let params = dir.join("params.json");
    let traces = dir.join("traces");
    ok(&pipesim_bin()
        .args(["gen-empirical", "--weeks", "2", "--seed", "5", "--out"])
        .arg(&db)
        .output()
        .unwrap());
    ok(&pipesim_bin()
        .arg("fit")
        .arg("--db")
        .arg(&db)
        .arg("--out")
        .arg(&params)
        .arg("--cpu")
        .output()
        .unwrap());
    ok(&pipesim_bin()
        .arg("sweep")
        .arg("--params")
        .arg(&params)
        .args([
            "--days",
            "0.25",
            "--arrival",
            "poisson:300",
            "--seeds",
            "2",
            "--jobs",
            "2",
            "--capacities",
            "2,4",
            "--cpu",
            "--trace-dir",
        ])
        .arg(&traces)
        .output()
        .unwrap());
    let mut files: Vec<String> = std::fs::read_dir(&traces)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    files.sort();
    assert_eq!(files.len(), 4, "2 caps x 2 seeds: {files:?}");
    assert!(files[0].starts_with("cell0000-") && files[0].ends_with(".pst"));
    // every dumped trace re-ingests and carries its cell's config
    for f in &files {
        let t = Trace::load(&traces.join(f)).unwrap();
        assert!(!t.is_empty());
        TraceWorkload::from_trace(&t).unwrap();
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn binary_and_json_params_drive_identical_runs() {
    let dir = tmpdir("paramsfmt");
    let p = quick_params(55);
    let bin = dir.join("p.bin");
    let json = dir.join("p.json");
    p.save(&bin).unwrap();
    p.save(&json).unwrap();
    let cfg = ExperimentConfig {
        name: "fmt".into(),
        seed: 2,
        horizon: DAY / 2.0,
        arrival: ArrivalSpec::Profile,
        ..Default::default()
    };
    let a = Experiment::new(cfg.clone(), SimParams::load(&bin).unwrap())
        .run()
        .unwrap();
    let b = Experiment::new(cfg, SimParams::load(&json).unwrap())
        .run()
        .unwrap();
    // the binary cache is bit-exact, JSON is round-trip-exact: digests
    // must agree with each other
    assert_eq!(a.digest(), b.digest());
    std::fs::remove_dir_all(dir).ok();
}
