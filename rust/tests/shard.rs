//! Sharded-sweep integration tests: the shard/merge oracle — merging
//! the N shard manifests of a sweep must reproduce the single-process
//! run byte-identically in per-cell digests and bit-identically in
//! group statistics — property-tested across shard counts, the 3-shard
//! disk round-trip, the named rejection errors, and the CLI surface
//! (`sweep --shard k/N` + `sweep-merge`).

use std::process::Command;
use std::sync::Arc;

use pipesim::coordinator::{
    fit_params, merge_shards, ArrivalSpec, ExperimentConfig, MergedSweep, ShardManifest,
    ShardSpec, SimParams, Sweep, SweepResult,
};

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("pipesim_shard_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn quick_params(seed: u64) -> SimParams {
    let db = pipesim::empirical::GroundTruth::new(seed).generate_weeks(2);
    fit_params(&db, None).unwrap()
}

/// The test grid: three capacity groups (one name carries commas and
/// quotes — the RFC-4180 regression rides through the whole pipeline),
/// three seeds each, nine cells total.
fn add_grid(sweep: &mut Sweep) {
    for (name, cap) in [("cap=2", 2), ("cap=4,\"hot\"", 4), ("cap=8", 8)] {
        let mut cfg = ExperimentConfig {
            name: name.into(),
            horizon: 3.0 * 3600.0,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 120.0,
            },
            record_traces: false,
            sample_interval: 600.0,
            ..Default::default()
        };
        cfg.infra.training_capacity = cap;
        sweep.add_replications(&cfg, 40, 3);
    }
}

fn run_full(params: &Arc<SimParams>) -> SweepResult {
    let mut sweep = Sweep::new(params.clone()).jobs(2);
    add_grid(&mut sweep);
    sweep.run().unwrap()
}

/// Run the same grid as `n` independent sharded sweeps and merge the
/// manifests through the wire format, exactly as the CLI would.
fn run_sharded(params: &Arc<SimParams>, n: usize) -> MergedSweep {
    let mut manifests = Vec::new();
    for k in 0..n {
        let spec = ShardSpec::new(k, n).unwrap();
        let mut sweep = Sweep::new(params.clone()).jobs(2).shard(Some(spec));
        add_grid(&mut sweep);
        let out = sweep.run().unwrap();
        manifests.push(ShardManifest::from_bytes(&out.manifest().to_bytes()).unwrap());
    }
    merge_shards(manifests).unwrap()
}

/// CSV rows minus the two wall-clock columns (`wall_secs`,
/// `wall_time_ms` — the only nondeterministic fields).
fn rows_sans_wall(csv: &str) -> Vec<Vec<String>> {
    csv.lines()
        .map(|l| {
            let fields: Vec<&str> = l.split(',').collect();
            let n = fields.len();
            fields
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != n - 3 && *i != n - 4)
                .map(|(_, f)| f.to_string())
                .collect()
        })
        .collect()
}

#[test]
fn merged_shards_reproduce_the_single_process_sweep() {
    let params = Arc::new(quick_params(71));
    let full = run_full(&params);
    let full_digests = full.digests();
    // the oracle holds for any shard count, including trivial (1) and
    // more shards than some strides can fill
    for n in [1usize, 2, 3, 5] {
        let merged = run_sharded(&params, n);
        assert_eq!(merged.shards, n);
        assert_eq!(merged.grid_len, 9);
        // per-cell digests byte-identical, in global grid order
        assert_eq!(merged.digests(), full_digests, "n={n}");
        // per-cell CSV identical except the wall columns
        assert_eq!(
            rows_sans_wall(&merged.to_csv()),
            rows_sans_wall(&full.to_csv()),
            "n={n}"
        );
        // group statistics bit-identical: the merge reassembles cells
        // in global order and reruns the same aggregation
        assert_eq!(merged.groups.len(), full.groups.len());
        for (m, f) in merged.groups.iter().zip(&full.groups) {
            assert_eq!(m.name, f.name);
            assert_eq!(m.cells, f.cells, "group '{}' n={n}", m.name);
            assert_eq!(m.wait.count, f.wait.count);
            assert_eq!(m.wait.sum.to_bits(), f.wait.sum.to_bits(), "n={n}");
            for (ms, fs) in m.metrics.iter().zip(&f.metrics) {
                assert_eq!(ms.name, fs.name);
                assert_eq!(ms.mean.to_bits(), fs.mean.to_bits(), "{} n={n}", ms.name);
                assert_eq!(ms.std_dev.to_bits(), fs.std_dev.to_bits(), "{}", ms.name);
                assert_eq!(ms.ci95.to_bits(), fs.ci95.to_bits(), "{}", ms.name);
                assert_eq!(ms.min.to_bits(), fs.min.to_bits(), "{}", ms.name);
                assert_eq!(ms.max.to_bits(), fs.max.to_bits(), "{}", ms.name);
                // sketch-merged quantiles are rank-bounded by design;
                // a 1-shard merge is exactly the single-process sketch
                assert!(ms.p50 >= ms.min && ms.p50 <= ms.max, "{}", ms.name);
                assert!(ms.p95 >= ms.p50 && ms.p95 <= ms.max, "{}", ms.name);
                if n == 1 {
                    assert_eq!(ms.p50.to_bits(), fs.p50.to_bits(), "{}", ms.name);
                    assert_eq!(ms.p95.to_bits(), fs.p95.to_bits(), "{}", ms.name);
                }
            }
        }
        // the comma-bearing group survives quoted in the merged CSV
        assert!(merged.to_csv().contains("\"cap=4,\"\"hot\"\"\""));
    }
}

#[test]
fn three_shard_disk_roundtrip_is_digest_identical() {
    let dir = tmpdir("disk");
    let params = Arc::new(quick_params(72));
    let full = run_full(&params);
    // each shard saves its manifest like an independent host would
    let mut paths = Vec::new();
    for k in 0..3 {
        let spec = ShardSpec::new(k, 3).unwrap();
        let mut sweep = Sweep::new(params.clone()).jobs(2).shard(Some(spec));
        add_grid(&mut sweep);
        let out = sweep.run().unwrap();
        let path = dir.join(format!("shard-{k}-of-3.psm"));
        out.manifest().save(&path).unwrap();
        paths.push(path);
    }
    // load in scrambled order: merge sorts by shard index
    let manifests: Vec<ShardManifest> = [2usize, 0, 1]
        .iter()
        .map(|&k| ShardManifest::load(&paths[k]).unwrap())
        .collect();
    let merged = merge_shards(manifests).unwrap();
    assert_eq!(merged.digests(), full.digests());
    assert_eq!(
        rows_sans_wall(&merged.to_csv()),
        rows_sans_wall(&full.to_csv())
    );
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn merge_rejects_broken_shard_sets_by_name() {
    let params = Arc::new(quick_params(73));
    let shard_run = |k: usize, n: usize| {
        let spec = ShardSpec::new(k, n).unwrap();
        let mut sweep = Sweep::new(params.clone()).jobs(2).shard(Some(spec));
        add_grid(&mut sweep);
        sweep.run().unwrap().manifest()
    };
    let (s0, s1, s2) = (shard_run(0, 3), shard_run(1, 3), shard_run(2, 3));
    // missing shard
    let err = merge_shards(vec![s0.clone(), s2.clone()]).unwrap_err();
    assert!(err.to_string().contains("missing shard 1/3"), "{err}");
    // overlapping (duplicate) shard
    let err = merge_shards(vec![s0.clone(), s1.clone(), s1.clone()]).unwrap_err();
    assert!(err.to_string().contains("supplied twice"), "{err}");
    // layout mismatch: a 2-shard manifest in a 3-shard set
    let foreign = shard_run(0, 2);
    let err = merge_shards(vec![foreign, s1.clone(), s2.clone()]).unwrap_err();
    assert!(err.to_string().contains("shard layout mismatch"), "{err}");
    // the intact set still merges
    assert!(merge_shards(vec![s0, s1, s2]).is_ok());
}

// ------------------------------------------------------------------
// CLI surface
// ------------------------------------------------------------------

fn pipesim_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_pipesim"))
}

fn ok(out: &std::process::Output) -> String {
    assert!(
        out.status.success(),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn cli_sharded_sweep_merges_to_the_single_process_output() {
    let dir = tmpdir("cli");
    let db = dir.join("db.json");
    let params = dir.join("params.json");
    ok(&pipesim_bin()
        .args(["gen-empirical", "--weeks", "2", "--seed", "9", "--out"])
        .arg(&db)
        .output()
        .unwrap());
    ok(&pipesim_bin()
        .arg("fit")
        .arg("--db")
        .arg(&db)
        .arg("--out")
        .arg(&params)
        .arg("--cpu")
        .output()
        .unwrap());
    let sweep_args = [
        "--days",
        "0.25",
        "--arrival",
        "poisson:300",
        "--seeds",
        "2",
        "--seed0",
        "7",
        "--capacities",
        "2,4",
        "--jobs",
        "2",
        "--cpu",
    ];
    // the single-process reference
    let full_csv = dir.join("full.csv");
    ok(&pipesim_bin()
        .arg("sweep")
        .arg("--params")
        .arg(&params)
        .args(sweep_args)
        .arg("--export")
        .arg(&full_csv)
        .output()
        .unwrap());
    // three shard runs, as three hosts would execute them
    let mut shard_paths = Vec::new();
    for k in 0..3 {
        let psm = dir.join(format!("s{k}.psm"));
        ok(&pipesim_bin()
            .arg("sweep")
            .arg("--params")
            .arg(&params)
            .args(sweep_args)
            .args(["--shard", &format!("{k}/3")])
            .arg("--manifest")
            .arg(&psm)
            .output()
            .unwrap());
        assert!(psm.exists(), "shard {k} manifest missing");
        shard_paths.push(psm);
    }
    // merge and compare to the reference export
    let merged_csv = dir.join("merged.csv");
    let merged_om = dir.join("merged.om");
    let shards_arg = shard_paths
        .iter()
        .map(|p| p.display().to_string())
        .collect::<Vec<_>>()
        .join(",");
    let out = ok(&pipesim_bin()
        .args(["sweep-merge", "--shards", &shards_arg])
        .arg("--export")
        .arg(&merged_csv)
        .arg("--metrics")
        .arg(&merged_om)
        .output()
        .unwrap());
    assert!(out.contains("sweep-merge: 4 cells from 3 shards"), "{out}");
    assert!(out.contains("pareto front"), "{out}");
    let full = std::fs::read_to_string(&full_csv).unwrap();
    let merged = std::fs::read_to_string(&merged_csv).unwrap();
    assert_eq!(rows_sans_wall(&merged), rows_sans_wall(&full));
    let om = std::fs::read_to_string(&merged_om).unwrap();
    assert!(om.contains("pipesim_sweep_cells 4"), "{om}");
    assert!(om.ends_with("# EOF\n"));
    // an incomplete shard set is rejected with the shard named
    let bad = pipesim_bin()
        .args(["sweep-merge", "--shards"])
        .arg(format!(
            "{},{}",
            shard_paths[0].display(),
            shard_paths[2].display()
        ))
        .output()
        .unwrap();
    assert!(!bad.status.success());
    let stderr = String::from_utf8_lossy(&bad.stderr);
    assert!(stderr.contains("missing shard 1/3"), "{stderr}");
    std::fs::remove_dir_all(dir).ok();
}
