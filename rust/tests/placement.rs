//! Heterogeneous-hardware oracles: the digest-compat guarantees the
//! hw-class subsystem makes (a degenerate class layout is byte-identical
//! to the homogeneous pool), placer-agreement laws on symmetric fleets,
//! cost accounting living strictly outside the digest, and per-class
//! failure blast radius.

use pipesim::coordinator::{
    fit_params, ArrivalSpec, Experiment, ExperimentConfig, ExperimentResult, SimParams,
    StrategySpec,
};
use pipesim::empirical::GroundTruth;
use pipesim::model::{ClusterFailureConfig, HwClass, HwClasses};

fn params() -> SimParams {
    let db = GroundTruth::new(66).generate_weeks(2);
    fit_params(&db, None).unwrap()
}

/// The shared saturated 6-hour workload; classes are the only knob.
fn cfg(name: &str, classes: Option<HwClasses>) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        name: name.into(),
        seed: 7,
        horizon: 21_600.0,
        arrival: ArrivalSpec::Poisson {
            mean_interarrival: 45.0,
        },
        record_traces: false,
        sample_interval: 600.0,
        ..Default::default()
    };
    cfg.infra.training_capacity = 3;
    if let Some(hw) = classes {
        let total: usize = hw.training.iter().map(|c| c.slots).sum();
        if total > 0 {
            cfg.infra.training_capacity = total;
        }
        cfg.infra.hw_classes = Some(hw);
    }
    cfg
}

fn run(cfg: ExperimentConfig, params: &SimParams) -> ExperimentResult {
    Experiment::new(cfg, params.clone()).run().unwrap()
}

fn classes(training: Vec<HwClass>, placer: &str) -> HwClasses {
    HwClasses {
        training,
        compute: Vec::new(),
        placer: StrategySpec::new(placer),
    }
}

#[test]
fn single_class_speed_one_is_digest_identical_to_homogeneous_pool() {
    // THE compat oracle: one class covering the whole cluster at speed
    // 1.0 with no cost knobs must replay the exact event stream of the
    // classless pool — byte-identical digest, not merely equal metrics
    let params = params();
    let base = run(cfg("homog", None), &params);
    let one = run(
        cfg("one-class", Some(classes(vec![HwClass::new("only", 3)], "fastest_fit"))),
        &params,
    );
    assert_eq!(
        base.digest(),
        one.digest(),
        "a degenerate single class changed simulation outcomes"
    );
    assert_eq!(base.events_processed, one.events_processed);
    // the class-aware run reports the subsystem's extras outside the digest
    assert!(base.class_util.is_empty() && base.placer.is_empty());
    assert_eq!(one.placer, "fastest_fit");
    assert_eq!(one.class_util.len(), 1);
    assert_eq!(one.class_util[0].0, "training/only");
    assert!(one.class_util[0].1 > 0.0, "saturated class shows utilization");
}

#[test]
fn cost_accrues_outside_the_digest() {
    // pricing the same degenerate class must not perturb a single event:
    // digest stays byte-identical to the classless baseline while the
    // new cost field becomes positive
    let params = params();
    let base = run(cfg("homog", None), &params);
    let priced = run(
        cfg(
            "priced",
            Some(classes(
                vec![HwClass::new("only", 3).with_cost(0.002)],
                "fastest_fit",
            )),
        ),
        &params,
    );
    assert_eq!(
        base.digest(),
        priced.digest(),
        "cost accounting leaked into the digest"
    );
    assert_eq!(base.cost, 0.0);
    assert!(priced.cost > 0.0, "busy priced slots accrued nothing");
}

#[test]
fn identical_classes_make_every_placer_agree() {
    // when every class has the same speed profile, the placement choice
    // cannot affect execution — all registered placers must agree on the
    // digest (fastest_fit == cheapest_fit == pack == spread)
    let params = params();
    let mk = |placer: &str| {
        run(
            cfg(
                &format!("sym-{placer}"),
                Some(classes(
                    vec![HwClass::new("a", 2), HwClass::new("b", 1)],
                    placer,
                )),
            ),
            &params,
        )
    };
    let reference = mk("fastest_fit");
    for placer in ["cheapest_fit", "pack", "spread"] {
        let r = mk(placer);
        assert_eq!(
            reference.digest(),
            r.digest(),
            "placer {placer} diverged on a symmetric fleet"
        );
    }
}

#[test]
fn fastest_and_cheapest_diverge_on_a_heterogeneous_fleet() {
    // a fleet with a fast-expensive and a slow-cheap class is the
    // placement trade-off in miniature: the two strategies must produce
    // different event streams, and chasing speed must cost more. Load is
    // kept moderate — placement is only a *choice* when more than one
    // class has free slots, so a fully saturated cluster would reduce
    // both placers to "take the only free slot"
    let params = params();
    let fleet = |placer: &str| {
        classes(
            vec![
                HwClass::new("a100", 1).with_speed(2.0).with_cost(0.004),
                HwClass::new("k80", 2).with_cost(0.001),
            ],
            placer,
        )
    };
    let mk = |name: &str, placer: &str| {
        let mut c = cfg(name, Some(fleet(placer)));
        c.horizon = 2.0 * 86_400.0;
        c.arrival = ArrivalSpec::Poisson {
            mean_interarrival: 450.0,
        };
        c
    };
    let fast = run(mk("fast", "fastest_fit"), &params);
    let cheap = run(mk("cheap", "cheapest_fit"), &params);
    assert_ne!(
        fast.digest(),
        cheap.digest(),
        "placement strategy had no effect on a heterogeneous fleet"
    );
    assert!(
        fast.cost > cheap.cost,
        "preferring the priced class must cost more ({} vs {})",
        fast.cost,
        cheap.cost
    );
    for r in [&fast, &cheap] {
        assert_eq!(r.arrived, r.completed + r.in_flight, "{}", r.name);
        assert!(r.completed > 0, "{}", r.name);
    }
}

#[test]
fn per_class_failures_stay_inside_their_class() {
    // MTBF configured on one class must take down only that class's
    // slots: the failure ledger shows hits on the frail class and zero
    // on the solid one, and conservation survives the churn
    let params = params();
    let r = run(
        cfg(
            "frail",
            Some(classes(
                vec![
                    HwClass::new("frail", 2)
                        .with_failures(ClusterFailureConfig::exponential(1200.0, 300.0)),
                    HwClass::new("solid", 2),
                ],
                "spread",
            )),
        ),
        &params,
    );
    assert!(r.failures > 0, "6h at 20min MTBF never failed");
    let count = |label: &str| {
        r.class_failures
            .iter()
            .find(|(l, _)| l == label)
            .map(|&(_, n)| n)
            .unwrap_or_else(|| panic!("missing class ledger entry {label}"))
    };
    assert_eq!(
        count("training/frail"),
        r.failures,
        "failures escaped the frail class's ledger"
    );
    assert_eq!(count("training/solid"), 0, "a solid slot failed");
    assert_eq!(r.arrived, r.completed + r.in_flight, "conservation under class failures");
    assert!(r.completed > 0);
    // determinism holds with the per-class failure RNG substream engaged
    let again = run(
        cfg(
            "frail",
            Some(classes(
                vec![
                    HwClass::new("frail", 2)
                        .with_failures(ClusterFailureConfig::exponential(1200.0, 300.0)),
                    HwClass::new("solid", 2),
                ],
                "spread",
            )),
        ),
        &params,
    );
    assert_eq!(r.digest(), again.digest(), "class failures nondeterministic");
}

#[test]
fn fw_profile_speed_overrides_class_speed() {
    // per-(framework, class) profiled speeds: a class that is fast only
    // for one framework must diverge from the same class being fast for
    // everything, and both diverge from the uniform baseline
    let params = params();
    let uniform = run(
        cfg("uniform", Some(classes(vec![HwClass::new("c", 3)], "fastest_fit"))),
        &params,
    );
    let all_fast = run(
        cfg(
            "all-fast",
            Some(classes(vec![HwClass::new("c", 3).with_speed(2.0)], "fastest_fit")),
        ),
        &params,
    );
    let tf_fast = run(
        cfg(
            "tf-fast",
            Some(classes(
                vec![HwClass::new("c", 3).with_fw_speed("tensorflow", 2.0)],
                "fastest_fit",
            )),
        ),
        &params,
    );
    assert_ne!(uniform.digest(), all_fast.digest(), "speed factor inert");
    assert_ne!(uniform.digest(), tf_fast.digest(), "fw profile inert");
    assert_ne!(all_fast.digest(), tf_fast.digest(), "fw profile equals class speed");
}
