//! Stub of the `xla` PJRT binding surface PipeSim uses.
//!
//! Environments without an XLA toolchain build against this crate
//! instead of the real bindings: every type checks, `PjRtClient::cpu()`
//! reports the runtime as unavailable, and PipeSim falls back to its
//! pure-Rust samplers (identical distributions, slower batches). All
//! types are plain data and therefore `Send + Sync`, which the parallel
//! sweep engine relies on.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type mirroring the real bindings' `xla::Error`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT unavailable (built against the xla stub; \
         link the real xla crate to execute AOT artifacts)"
    ))
}

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Copy {
    fn from_f32(v: f32) -> Self;
    fn to_f32(self) -> f32;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }
    fn to_f32(self) -> f32 {
        self
    }
}

/// Host-side tensor of f32 data with a shape.
#[derive(Debug, Clone, Default)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }

    /// Reshape without changing element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Read the buffer back as a flat vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Destructure a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (opaque in the stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// A computation ready for compilation.
#[derive(Debug, Clone)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// Device-resident buffer handle.
#[derive(Debug, Clone)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    /// The stub always reports the CPU PJRT plugin as unavailable, which
    /// makes `Runtime::load` fail cleanly and the caller fall back to
    /// the pure-Rust sampler path.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not succeed");
        assert!(err.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.dims(), &[6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn types_are_send_sync() {
        fn check<T: Send + Sync>() {}
        check::<Error>();
        check::<Literal>();
        check::<PjRtClient>();
        check::<PjRtLoadedExecutable>();
        check::<PjRtBuffer>();
        check::<HloModuleProto>();
        check::<XlaComputation>();
    }
}
