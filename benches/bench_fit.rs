//! Fitting-pipeline bench (Fig 8 / 9 / 10 inputs): EM over the AOT
//! artifacts vs the pure-Rust baseline, curve NLLS, exp-Weibull MLE, and
//! the 168-cluster arrival-profile fit.
//!
//! Run: `cargo bench --bench bench_fit`

use std::sync::Arc;

use pipesim::arrivals::ArrivalProfile;
use pipesim::empirical::GroundTruth;
use pipesim::runtime::fitter::{fit_gmm1_cpu, fit_gmm3_cpu};
use pipesim::runtime::{fit_gmm1, fit_gmm3, Runtime, K1, K3};
use pipesim::stats::fit::{fit_exp_curve, fit_expweibull};
use pipesim::stats::rng::Pcg64;
use pipesim::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::with_budget(std::time::Duration::from_millis(200), 3);
    let db = GroundTruth::new(9).generate_weeks(6);
    let runtime = Runtime::load_default().map(Arc::new);

    let assets = db.asset_log_matrix();
    let spark_logs: Vec<f64> = db
        .durations_for(pipesim::model::Framework::SparkML)
        .into_iter()
        .map(|d| d.ln())
        .collect();

    // one EM iteration + full fit, PJRT vs CPU
    if let Some(rt) = &runtime {
        b.bench_once("fit_gmm3 K=50 (60 iters) [pjrt]", || {
            let mut rng = Pcg64::new(1);
            black_box(fit_gmm3(rt, &assets, &mut rng, 60, 1e-6).unwrap());
        });
        b.bench_once("fit_gmm1 K=8 (80 iters) [pjrt]", || {
            let mut rng = Pcg64::new(2);
            black_box(fit_gmm1(rt, &spark_logs, &mut rng, 80, 1e-7).unwrap());
        });
    } else {
        println!("# artifacts not built: PJRT fits skipped");
    }
    b.bench_once("fit_gmm3 K=50 (60 iters) [cpu]", || {
        let mut rng = Pcg64::new(1);
        black_box(fit_gmm3_cpu(&assets, K3, &mut rng, 60, 1e-6).unwrap());
    });
    b.bench_once("fit_gmm1 K=8 (80 iters) [cpu]", || {
        let mut rng = Pcg64::new(2);
        black_box(fit_gmm1_cpu(&spark_logs, K1, &mut rng, 80, 1e-7));
    });

    // Fig 9a curve fit
    let (xs, ys) = db.preproc_pairs();
    b.bench_once("fit_exp_curve (NLLS, Fig 9a)", || {
        black_box(fit_exp_curve(&xs, &ys).unwrap());
    });

    // interarrival MLE + the full 168-cluster profile (Fig 10 / 12)
    let gaps: Vec<f64> = db.interarrivals().into_iter().filter(|&g| g > 0.0).collect();
    let sub: Vec<f64> = gaps.iter().take(5000).cloned().collect();
    b.bench_once("fit_expweibull MLE (5k gaps)", || {
        black_box(fit_expweibull(&sub).unwrap());
    });
    b.bench_once("arrival profile fit (168 clusters)", || {
        let mut rng = Pcg64::new(3);
        black_box(ArrivalProfile::fit(&db, &mut rng).unwrap());
    });
}
