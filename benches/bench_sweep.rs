//! Sweep-engine bench: multicore scaling of the parallel replication
//! engine over a 32-cell (config × seed) grid, with the determinism
//! invariant checked at every worker count — parallel results must be
//! byte-identical to the serial baseline — plus a sharded-vs-single
//! section over a 10k-cell grid exercising `--shard` + `sweep-merge`.
//!
//! Emits `BENCH_sweep.json` so the scaling trajectory is tracked across
//! PRs. Run: `cargo bench --bench bench_sweep`

use std::sync::Arc;

use pipesim::coordinator::{
    fit_params, merge_shards, ArrivalSpec, ExperimentConfig, ShardManifest, ShardSpec, Sweep,
    SweepResult,
};
use pipesim::empirical::GroundTruth;
use pipesim::runtime::Runtime;
use pipesim::util::Json;

const SEEDS_PER_CONFIG: usize = 8;
const CAPACITIES: [usize; 4] = [4, 6, 8, 12];
const PIPELINES_PER_CELL: u64 = 2_000;

// the sharded section: 25 groups × 400 seeds = 10 000 tiny cells
const BIG_GROUPS: usize = 25;
const BIG_SEEDS: usize = 400;
const BIG_PIPELINES: u64 = 8;
const BIG_SHARDS: usize = 4;

fn run_with(params: &Arc<pipesim::coordinator::SimParams>, rt: &Option<Arc<Runtime>>, jobs: usize) -> SweepResult {
    let mut sweep = Sweep::new(params.clone()).with_runtime(rt.clone()).jobs(jobs);
    for cap in CAPACITIES {
        let mut cfg = ExperimentConfig {
            name: format!("cap{cap}"),
            horizon: f64::MAX / 4.0,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 44.0,
            },
            max_pipelines: Some(PIPELINES_PER_CELL),
            record_traces: false,
            sample_interval: 3600.0,
            ..Default::default()
        };
        cfg.infra.training_capacity = cap;
        sweep.add_replications(&cfg, 1, SEEDS_PER_CONFIG);
    }
    sweep.run().expect("sweep")
}

/// One pass over the 10k-cell grid — the whole grid when `shard` is
/// `None`, one stride of it otherwise. Auto worker count either way.
fn run_big(
    params: &Arc<pipesim::coordinator::SimParams>,
    rt: &Option<Arc<Runtime>>,
    shard: Option<ShardSpec>,
) -> SweepResult {
    let mut sweep = Sweep::new(params.clone())
        .with_runtime(rt.clone())
        .jobs(0)
        .shard(shard);
    for g in 0..BIG_GROUPS {
        let mut cfg = ExperimentConfig {
            name: format!("grid{g:02}"),
            horizon: f64::MAX / 4.0,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 44.0,
            },
            max_pipelines: Some(BIG_PIPELINES),
            record_traces: false,
            sample_interval: 3600.0,
            ..Default::default()
        };
        cfg.infra.training_capacity = 4 + (g % 8);
        sweep.add_replications(&cfg, 1, BIG_SEEDS);
    }
    sweep.run().expect("sharded sweep")
}

fn main() {
    let db = GroundTruth::new(5).generate_weeks(3);
    let runtime = Runtime::load_default().map(Arc::new);
    println!(
        "# sampler backend: {}",
        if runtime.is_some() { "pjrt" } else { "cpu" }
    );
    let params = Arc::new(fit_params(&db, runtime.clone()).expect("fit"));
    let cells = CAPACITIES.len() * SEEDS_PER_CONFIG;
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("# {cells} cells x {PIPELINES_PER_CELL} pipelines, {cores} cores available");

    // warm-up pass so allocator/page-cache effects don't bias jobs=1
    let _ = run_with(&params, &runtime, 0);

    println!("jobs,wall_secs,speedup_vs_1,events_per_sec,identical_to_serial");
    let serial = run_with(&params, &runtime, 1);
    let base_digests = serial.digests();
    println!(
        "1,{:.3},1.00,{:.0},true",
        serial.wall_secs,
        serial.events_per_sec()
    );

    let mut measured: Vec<(usize, f64, f64)> = vec![(1, serial.wall_secs, serial.events_per_sec())];
    for jobs in [2usize, 4, 8] {
        let r = run_with(&params, &runtime, jobs);
        let identical = r.digests() == base_digests;
        println!(
            "{jobs},{:.3},{:.2},{:.0},{identical}",
            r.wall_secs,
            serial.wall_secs / r.wall_secs,
            r.events_per_sec()
        );
        assert!(identical, "jobs={jobs} diverged from the serial baseline");
        measured.push((jobs, r.wall_secs, r.events_per_sec()));
    }

    let best = measured
        .iter()
        .cloned()
        .fold((1, f64::INFINITY, 0.0), |acc, m| if m.1 < acc.1 { m } else { acc });
    // sharded-vs-single: split the 10k-cell grid into BIG_SHARDS
    // stride shards (each run as an independent sweep, modelling one
    // host per shard), round-trip every manifest through its wire
    // format, merge, and demand digest identity with the single run
    let big_cells = BIG_GROUPS * BIG_SEEDS;
    println!("# sharded sweep: {big_cells} cells split {BIG_SHARDS} ways");
    let single = run_big(&params, &runtime, None);
    let mut manifests = Vec::new();
    let mut shard_wall_total = 0.0_f64;
    let mut shard_wall_max = 0.0_f64;
    for k in 0..BIG_SHARDS {
        let spec = ShardSpec::new(k, BIG_SHARDS).expect("shard spec");
        let r = run_big(&params, &runtime, Some(spec));
        shard_wall_total += r.wall_secs;
        shard_wall_max = shard_wall_max.max(r.wall_secs);
        manifests.push(ShardManifest::from_bytes(&r.manifest().to_bytes()).expect("manifest"));
    }
    let merge_t0 = std::time::Instant::now();
    let merged = merge_shards(manifests).expect("merge");
    let merge_secs = merge_t0.elapsed().as_secs_f64();
    let sharded_identical = merged.digests() == single.digests();
    assert!(sharded_identical, "sharded merge diverged from single-process sweep");
    println!("mode,cells,single_wall_secs,shard_wall_max,shard_wall_total,merge_secs,identical");
    println!(
        "sharded,{big_cells},{:.3},{shard_wall_max:.3},{shard_wall_total:.3},\
         {merge_secs:.4},{sharded_identical}",
        single.wall_secs
    );

    let json = Json::obj(vec![
        ("bench", Json::Str("sweep".into())),
        ("cells", Json::Num(cells as f64)),
        ("pipelines_per_cell", Json::Num(PIPELINES_PER_CELL as f64)),
        ("cores_available", Json::Num(cores as f64)),
        ("wall_secs_jobs1", Json::Num(serial.wall_secs)),
        ("wall_secs_best", Json::Num(best.1)),
        ("best_jobs", Json::Num(best.0 as f64)),
        ("speedup_best", Json::Num(serial.wall_secs / best.1)),
        ("events_per_sec_best", Json::Num(best.2)),
        ("deterministic", Json::Bool(true)),
        ("sharded_cells", Json::Num(big_cells as f64)),
        ("sharded_shards", Json::Num(BIG_SHARDS as f64)),
        ("sharded_single_wall_secs", Json::Num(single.wall_secs)),
        ("sharded_shard_wall_max", Json::Num(shard_wall_max)),
        ("sharded_shard_wall_total", Json::Num(shard_wall_total)),
        ("sharded_merge_secs", Json::Num(merge_secs)),
        ("sharded_identical", Json::Bool(sharded_identical)),
    ]);
    std::fs::write("BENCH_sweep.json", json.to_string()).expect("write BENCH_sweep.json");
    println!("# wrote BENCH_sweep.json (speedup x{:.2} at {} jobs)", serial.wall_secs / best.1, best.0);
}
