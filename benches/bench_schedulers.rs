//! Operational-strategy ablation (Fig 4's scheduler concept + DESIGN.md
//! ablations): every *registered* scheduling strategy under saturation,
//! and every registered retraining trigger trading model quality against
//! infrastructure load.
//!
//! Emits `BENCH_schedulers.json` (wait-time mean/p95 per scheduler) so
//! the strategy trade-off surface is tracked across PRs alongside the
//! simulator/sweep perf trajectories.
//!
//! Run: `cargo bench --bench bench_schedulers`

use std::sync::Arc;

use pipesim::coordinator::config::RuntimeViewConfig;
use pipesim::coordinator::result::series;
use pipesim::coordinator::{
    fit_params, scheduler_names, trigger_names, ArrivalSpec, Experiment, ExperimentConfig,
    StrategySpec,
};
use pipesim::des::DAY;
use pipesim::empirical::GroundTruth;
use pipesim::runtime::Runtime;
use pipesim::stats::quantile;
use pipesim::util::bench::Bench;
use pipesim::util::Json;

/// p95 of training-queue wait: the recorded nonzero waits padded with
/// the zero-wait grants (wait_stats counts every request).
fn wait_p95(r: &pipesim::coordinator::ExperimentResult) -> f64 {
    let mut waits: Vec<f64> = r
        .tsdb
        .find_tagged(series::TASK_WAIT, "resource", "training")
        .iter()
        .flat_map(|&h| r.tsdb.series(h).values.iter().copied())
        .collect();
    let total = r.wait_training.count as usize;
    if waits.len() < total {
        waits.resize(total, 0.0);
    }
    if waits.is_empty() {
        return 0.0;
    }
    quantile(&waits, 0.95)
}

fn main() {
    let db = GroundTruth::new(17).generate_weeks(4);
    let runtime = Runtime::load_default().map(Arc::new);
    let backend = if runtime.is_some() { "pjrt" } else { "cpu" };
    let params = fit_params(&db, runtime.clone()).expect("fit");
    let mut b = Bench::with_budget(std::time::Duration::from_millis(100), 3);

    println!("# scheduler ablation (7 days, training capacity 4, registry-driven)");
    println!("scheduler,mean_wait_s,p95_wait_s,max_wait_s,completed,util_training,preemptions");
    let mut sched_rows = Vec::new();
    for name in scheduler_names() {
        let mut out = None;
        b.bench_once(format!("7-day run [{name}]"), || {
            let mut cfg = ExperimentConfig {
                name: name.clone(),
                seed: 2,
                horizon: 7.0 * DAY,
                arrival: ArrivalSpec::Profile,
                // traces on: the p95 comes from the task_wait series
                record_traces: true,
                ..Default::default()
            };
            cfg.infra.training_capacity = 4;
            cfg.infra.scheduler = StrategySpec::new(&name);
            let r = Experiment::new(cfg, params.clone())
                .with_runtime(runtime.clone())
                .run()
                .expect("run");
            let max_wait = if r.wait_training.count > 0 {
                r.wait_training.max
            } else {
                0.0
            };
            out = Some((
                r.wait_training.mean(),
                wait_p95(&r),
                max_wait,
                r.completed,
                r.util_training,
                r.preemptions,
            ));
        });
        let (mw, p95, xw, c, u, pe) = out.unwrap();
        println!("{name},{mw:.1},{p95:.1},{xw:.0},{c},{u:.3},{pe}");
        sched_rows.push(Json::obj(vec![
            ("name", Json::Str(name.clone())),
            ("wait_mean_s", Json::Num(mw)),
            ("wait_p95_s", Json::Num(p95)),
            ("wait_max_s", Json::Num(xw)),
            ("completed", Json::Num(c as f64)),
            ("util_training", Json::Num(u)),
            ("preemptions", Json::Num(pe as f64)),
        ]));
    }

    // wide-train ablation: 2-slot training jobs create head-of-line
    // blocking on the training cluster — the regime preemption and
    // backfill exist for (unit-slot rows above keep their own trend)
    println!("# wide-train ablation (7 days, capacity 4, train_slots 2)");
    println!("scheduler,mean_wait_s,completed,util_training,preemptions");
    let mut wide_rows = Vec::new();
    for name in ["fifo", "easy_backfill", "priority", "preemptive_priority"] {
        let mut out = None;
        b.bench_once(format!("7-day wide run [{name}]"), || {
            let mut cfg = ExperimentConfig {
                name: format!("{name}-w2"),
                seed: 2,
                horizon: 7.0 * DAY,
                arrival: ArrivalSpec::Profile,
                record_traces: false,
                ..Default::default()
            };
            cfg.infra.training_capacity = 4;
            cfg.infra.train_slots = 2;
            cfg.infra.scheduler = StrategySpec::new(name);
            let r = Experiment::new(cfg, params.clone())
                .with_runtime(runtime.clone())
                .run()
                .expect("run");
            out = Some((
                r.wait_training.mean(),
                r.completed,
                r.util_training,
                r.preemptions,
            ));
        });
        let (mw, c, u, pe) = out.unwrap();
        println!("{name},{mw:.1},{c},{u:.3},{pe}");
        wide_rows.push(Json::obj(vec![
            ("name", Json::Str(name.into())),
            ("train_slots", Json::Num(2.0)),
            ("wait_mean_s", Json::Num(mw)),
            ("completed", Json::Num(c as f64)),
            ("util_training", Json::Num(u)),
            ("preemptions", Json::Num(pe as f64)),
        ]));
    }

    println!("# trigger ablation (14 days, runtime view on, registry-driven)");
    println!("trigger,retrains,mean_perf,util_training,completed");
    let mut trig_rows = Vec::new();
    for name in trigger_names() {
        let mut out = None;
        b.bench_once(format!("14-day run [{name}]"), || {
            let cfg = ExperimentConfig {
                name: name.clone(),
                seed: 2,
                horizon: 14.0 * DAY,
                arrival: ArrivalSpec::Poisson {
                    mean_interarrival: 300.0,
                },
                record_traces: false,
                runtime_view: RuntimeViewConfig {
                    enabled: true,
                    detector_interval: 3600.0,
                    decay_per_day: 0.02,
                    sudden_drift_prob: 0.02,
                    sudden_drift_drop: 0.08,
                    trigger: StrategySpec::new(&name),
                    max_models: 1000,
                },
                ..Default::default()
            };
            let r = Experiment::new(cfg, params.clone())
                .with_runtime(runtime.clone())
                .run()
                .expect("run");
            out = Some((
                r.retrains_triggered,
                r.final_mean_performance,
                r.util_training,
                r.completed,
            ));
        });
        let (rt_, p, u, c) = out.unwrap();
        println!("{name},{rt_},{p:.3},{u:.3},{c}");
        trig_rows.push(Json::obj(vec![
            ("name", Json::Str(name.clone())),
            ("retrains", Json::Num(rt_ as f64)),
            ("mean_perf", Json::Num(p)),
            ("util_training", Json::Num(u)),
            ("completed", Json::Num(c as f64)),
        ]));
    }

    let json = Json::obj(vec![
        ("bench", Json::Str("schedulers".into())),
        ("backend", Json::Str(backend.into())),
        ("schedulers", Json::Arr(sched_rows)),
        ("schedulers_wide", Json::Arr(wide_rows)),
        ("triggers", Json::Arr(trig_rows)),
    ]);
    std::fs::write("BENCH_schedulers.json", json.to_string())
        .expect("write BENCH_schedulers.json");
    println!("# wrote BENCH_schedulers.json");
}
