//! Operational-strategy ablation (Fig 4's scheduler concept + DESIGN.md
//! ablations): queue disciplines under saturation, and retraining trigger
//! policies trading model quality against infrastructure load.
//!
//! Run: `cargo bench --bench bench_schedulers`

use std::sync::Arc;

use pipesim::coordinator::config::RuntimeViewConfig;
use pipesim::coordinator::{fit_params, ArrivalSpec, Experiment, ExperimentConfig, TriggerPolicy};
use pipesim::des::resource::Discipline;
use pipesim::des::DAY;
use pipesim::empirical::GroundTruth;
use pipesim::runtime::Runtime;
use pipesim::util::bench::Bench;

fn main() {
    let db = GroundTruth::new(17).generate_weeks(4);
    let runtime = Runtime::load_default().map(Arc::new);
    let params = fit_params(&db, runtime.clone()).expect("fit");
    let mut b = Bench::with_budget(std::time::Duration::from_millis(100), 3);

    println!("# discipline ablation (7 days, training capacity 4)");
    println!("discipline,mean_wait_s,max_wait_s,completed,util_training");
    for (name, d) in [
        ("fifo", Discipline::Fifo),
        ("sjf", Discipline::ShortestJobFirst),
        ("priority", Discipline::Priority),
    ] {
        let mut out = None;
        b.bench_once(format!("7-day run [{name}]"), || {
            let mut cfg = ExperimentConfig {
                name: name.into(),
                seed: 2,
                horizon: 7.0 * DAY,
                arrival: ArrivalSpec::Profile,
                record_traces: false,
                ..Default::default()
            };
            cfg.infra.training_capacity = 4;
            cfg.infra.discipline = d;
            let r = Experiment::new(cfg, params.clone())
                .with_runtime(runtime.clone())
                .run()
                .expect("run");
            out = Some((
                r.wait_training.mean(),
                r.wait_training.max,
                r.completed,
                r.util_training,
            ));
        });
        let (mw, xw, c, u) = out.unwrap();
        println!("{name},{mw:.1},{xw:.0},{c},{u:.3}");
    }

    println!("# trigger-policy ablation (14 days, runtime view on)");
    println!("policy,retrains,mean_perf,util_training,completed");
    for (name, policy) in [
        ("never", TriggerPolicy::Never),
        ("eager", TriggerPolicy::Eager),
        ("threshold", TriggerPolicy::DriftThreshold { threshold: 0.05 }),
        (
            "offpeak",
            TriggerPolicy::OffPeak {
                threshold: 0.05,
                max_intensity: 0.5,
            },
        ),
    ] {
        let mut out = None;
        b.bench_once(format!("14-day run [{name}]"), || {
            let cfg = ExperimentConfig {
                name: name.into(),
                seed: 2,
                horizon: 14.0 * DAY,
                arrival: ArrivalSpec::Poisson {
                    mean_interarrival: 300.0,
                },
                record_traces: false,
                runtime_view: RuntimeViewConfig {
                    enabled: true,
                    detector_interval: 3600.0,
                    decay_per_day: 0.02,
                    sudden_drift_prob: 0.02,
                    sudden_drift_drop: 0.08,
                    trigger: policy,
                    max_models: 1000,
                },
                ..Default::default()
            };
            let r = Experiment::new(cfg, params.clone())
                .with_runtime(runtime.clone())
                .run()
                .expect("run");
            out = Some((
                r.retrains_triggered,
                r.final_mean_performance,
                r.util_training,
                r.completed,
            ));
        });
        let (rt_, p, u, c) = out.unwrap();
        println!("{name},{rt_},{p:.3},{u:.3},{c}");
    }
}
