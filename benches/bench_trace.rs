//! Trace subsystem benchmarks: codec throughput (events/sec write and
//! read), capture overhead versus a plain run, the `NullSink`
//! zero-allocation guard on the event path, and the streaming-sink
//! throughput + bounded-allocation guard.
//!
//! Emits `BENCH_trace.json` for the CI perf trajectory. The allocation
//! guards are hard assertions: emitting events into the `NullSink` must
//! perform ZERO heap allocations, and the `StreamingPstSink` record
//! path must perform ZERO allocations once its bounded buffers (intern
//! table, record scratch, `BufWriter` block) are warm — that is the
//! memory-flat-capture claim. If either ever allocates, this bench (and
//! CI) fails.
//!
//! Run: `cargo bench --bench bench_trace`

use pipesim::analytics::TraceSummary;
use pipesim::coordinator::{fit_params, ArrivalSpec, Experiment, ExperimentConfig};
use pipesim::des::DAY;
use pipesim::empirical::GroundTruth;
use pipesim::model::{Framework, TaskType};
use pipesim::trace::{NullSink, StreamingPstSink, Trace, TraceEvent, TraceEventKind, TraceSink};
use pipesim::util::alloc::{allocs, CountingAlloc};
use pipesim::util::bench::{black_box, Bench};
use pipesim::util::Json;

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn main() {
    let db = GroundTruth::new(23).generate_weeks(2);
    let params = fit_params(&db, None).expect("fit");
    let mut b = Bench::with_budget(std::time::Duration::from_millis(200), 3);
    let mut report: Vec<(&str, Json)> = vec![("bench", Json::Str("trace".into()))];

    // --- NullSink zero-allocation guard --------------------------------
    {
        let mut sink = NullSink;
        let n = 1_000_000u64;
        // warm up whatever lazy state exists before snapshotting
        sink.record(&TraceEvent {
            t: 0.0,
            kind: TraceEventKind::ArrivalGapDrawn { gap: 1.0 },
        });
        let before = allocs();
        for i in 0..n {
            let ev = TraceEvent {
                t: i as f64,
                kind: TraceEventKind::TaskDone {
                    pid: i as u32,
                    task: TaskType::Train,
                    framework: Some(Framework::TensorFlow),
                    exec: 42.0,
                },
            };
            sink.record(black_box(&ev));
        }
        let delta = allocs() - before;
        println!("# NullSink: {delta} allocations across {n} events");
        assert_eq!(
            delta, 0,
            "NullSink event path must be allocation-free (got {delta} allocs)"
        );
        report.push(("null_sink_allocs", Json::Num(delta as f64)));
        report.push(("null_sink_events", Json::Num(n as f64)));
    }

    // --- capture overhead vs plain run ---------------------------------
    let run = |capture: bool| {
        let cfg = ExperimentConfig {
            name: if capture { "cap" } else { "plain" }.into(),
            seed: 5,
            horizon: 2.0 * DAY,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 60.0,
            },
            record_traces: false,
            capture_trace: capture,
            ..Default::default()
        };
        Experiment::new(cfg, params.clone()).run().expect("run")
    };
    let mut plain_secs = 0.0;
    b.bench_once("2-day run, capture off", || {
        let r = run(false);
        plain_secs = r.wall_secs;
        black_box(r.events_processed);
    });
    let mut capture_secs = 0.0;
    let mut trace: Option<Trace> = None;
    b.bench_once("2-day run, capture on", || {
        let mut r = run(true);
        capture_secs = r.wall_secs;
        trace = r.trace.take();
    });
    let trace = trace.expect("capture produced a trace");
    let overhead_pct = if plain_secs > 0.0 {
        100.0 * (capture_secs / plain_secs - 1.0)
    } else {
        0.0
    };
    println!(
        "# capture overhead: {overhead_pct:.1}% ({} events captured)",
        trace.len()
    );
    report.push(("capture_overhead_pct", Json::Num(overhead_pct)));
    report.push(("captured_events", Json::Num(trace.len() as f64)));

    // --- codec throughput ----------------------------------------------
    let mut bytes = Vec::new();
    let m = b
        .bench("encode trace", || {
            bytes = black_box(trace.to_bytes());
        })
        .clone();
    let write_eps = trace.len() as f64 / m.mean.as_secs_f64().max(1e-12);
    let m = b
        .bench("decode trace", || {
            black_box(Trace::from_bytes(&bytes).expect("decode"));
        })
        .clone();
    let read_eps = trace.len() as f64 / m.mean.as_secs_f64().max(1e-12);
    let bytes_per_event = bytes.len() as f64 / trace.len().max(1) as f64;
    println!(
        "# codec: write {write_eps:.0} events/s, read {read_eps:.0} events/s, \
         {bytes_per_event:.1} B/event"
    );
    report.push(("write_events_per_sec", Json::Num(write_eps)));
    report.push(("read_events_per_sec", Json::Num(read_eps)));
    report.push(("bytes_per_event", Json::Num(bytes_per_event)));
    report.push(("trace_bytes", Json::Num(bytes.len() as f64)));

    // --- streaming sink: throughput + bounded-allocation guard ---------
    {
        let dir = std::env::temp_dir().join(format!("pipesim_bench_stream_{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("temp dir");
        let path = dir.join("stream.pst");
        let cfg = ExperimentConfig {
            name: "stream-bench".into(),
            ..Default::default()
        };
        let mut sink = StreamingPstSink::create(&path, &cfg.trace_meta()).expect("create");
        // replay the captured run's real event mix through the sink.
        // Warm up every bounded buffer first: all record kinds intern
        // their strings, the scratch reaches its final capacity, and the
        // BufWriter cycles through several flushes.
        let warmup = trace.events.len().min(50_000);
        for ev in &trace.events[..warmup] {
            sink.record(ev);
        }
        let before = allocs();
        let passes = 4u64;
        let t0 = std::time::Instant::now();
        for _ in 0..passes {
            for ev in &trace.events {
                sink.record(black_box(ev));
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        let streamed = passes * trace.events.len() as u64;
        let delta = allocs() - before;
        let stream_eps = streamed as f64 / secs.max(1e-12);
        println!(
            "# streaming sink: {stream_eps:.0} events/s, {delta} allocations across {streamed} \
             events after warmup"
        );
        assert_eq!(
            delta, 0,
            "StreamingPstSink record path must hold O(1) memory (got {delta} allocs)"
        );
        sink.finish().expect("finalize streamed trace");
        // the streamed file re-reads to exactly what the sink was fed
        let loaded = Trace::load(&path).expect("streamed file decodes");
        assert_eq!(loaded.events.len() as u64, warmup as u64 + streamed);
        report.push(("stream_write_events_per_sec", Json::Num(stream_eps)));
        report.push(("stream_allocs_after_warmup", Json::Num(delta as f64)));

        // --- streamed stats: summarize the file without materializing --
        let total = loaded.events.len() as f64;
        drop(loaded);
        let m = b
            .bench("streamed stats over .pst file", || {
                let (_, s) = TraceSummary::from_file(&path).expect("streamed stats");
                black_box(s.events);
            })
            .clone();
        let stats_eps = total / m.mean.as_secs_f64().max(1e-12);
        println!("# streamed stats: {stats_eps:.0} events/s over the file-backed scanner");
        report.push(("streamed_stats_events_per_sec", Json::Num(stats_eps)));
        std::fs::remove_dir_all(&dir).ok();
    }

    let json = Json::obj(report);
    std::fs::write("BENCH_trace.json", json.to_string()).expect("write BENCH_trace.json");
    println!("# wrote BENCH_trace.json");
}
