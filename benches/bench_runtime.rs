//! Runtime-layer bench: PJRT artifact sampling vs the pure-Rust
//! baseline — the cost of the batched hot path the coordinator drives.
//!
//! Skips the PJRT cases when `artifacts/` is not built.
//! Run: `cargo bench --bench bench_runtime`

use std::sync::Arc;

use pipesim::runtime::pool::{Backend, PreprocDurationPool, SamplePool1, SamplePool3};
use pipesim::runtime::{Runtime, K1, K3, N_SAMPLE};
use pipesim::stats::dist::LogNormal;
use pipesim::stats::gmm::{Gmm1, Gmm3};
use pipesim::stats::rng::Pcg64;
use pipesim::stats::ExpCurve;
use pipesim::util::bench::{black_box, Bench};

fn toy_gmm3() -> Gmm3 {
    let mut logw = vec![-60.0f64; K3];
    logw[0] = 0.0;
    let eye = [[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]];
    Gmm3 {
        logw,
        mu: vec![[8.0, 3.0, 12.0]; K3],
        cchol: vec![eye; K3],
        pchol: vec![eye; K3],
    }
}

fn toy_gmm1() -> Gmm1 {
    let mut logw = vec![-60.0f64; K1];
    logw[0] = 0.0;
    Gmm1 {
        logw,
        mu: vec![3.0; K1],
        logsd: vec![0.5; K1],
    }
}

fn main() {
    let mut b = Bench::new();
    let runtime = Runtime::load_default().map(Arc::new);

    let backends: Vec<(&str, Backend)> = match &runtime {
        Some(rt) => vec![
            ("pjrt", Backend::Runtime(rt.clone())),
            ("cpu", Backend::Cpu),
        ],
        None => {
            println!("# artifacts not built: PJRT cases skipped");
            vec![("cpu", Backend::Cpu)]
        }
    };

    for (name, backend) in &backends {
        let mut pool3 = SamplePool3::new(backend.clone(), toy_gmm3(), Pcg64::new(1));
        b.bench(format!("pool3 next() amortized [{name}]"), || {
            black_box(pool3.next().unwrap());
        });

        let mut pool1 = SamplePool1::new(backend.clone(), toy_gmm1(), Pcg64::new(2));
        b.bench(format!("pool1 next() amortized [{name}]"), || {
            black_box(pool1.next().unwrap());
        });

        let mut pre = PreprocDurationPool::new(
            backend.clone(),
            ExpCurve {
                a: 0.018,
                b: 1.330,
                c: 2.156,
            },
            LogNormal::new(-1.0, 0.15),
            Pcg64::new(3),
        );
        let logsizes = vec![9.0f64; N_SAMPLE];
        b.bench_once(format!("preproc batch of {N_SAMPLE} [{name}]"), || {
            black_box(pre.durations(&logsizes).unwrap());
        });
    }

    // raw artifact execution cost (per PJRT call)
    if let Some(rt) = &runtime {
        let g = toy_gmm3();
        let mut rng = Pcg64::new(4);
        let mut u = vec![0f32; N_SAMPLE];
        let mut z = vec![0f32; N_SAMPLE * 3];
        rng.fill_uniform_f32(&mut u);
        rng.fill_normal_f32(&mut z);
        b.bench("raw gmm_sample3 execute (4096 draws)", || {
            black_box(rt.sample3(&g, &u, &z).unwrap());
        });
    }
}
