//! Task-fault perf + reliability ablation (DESIGN.md robustness
//! direction): what the task-fault subsystem costs when it is off, how
//! throughput and outcomes respond to fault pressure, and how the retry
//! policy trades wait against abandonment at fixed pressure.
//!
//! Three claims tracked across PRs via `BENCH_faults.json`:
//!   1. fault-off overhead is zero in work terms — an inert fault model
//!      (mean time-to-fault far past any attempt) is digest-identical
//!      to no model at all, and its wall-clock stays within noise;
//!   2. faults-on throughput (events/s) degrades gracefully with fault
//!      pressure (mean time-to-fault sweep) while the four-way
//!      conservation law holds exactly;
//!   3. retry policies meaningfully trade deadline attainment, wasted
//!      work, and abandonment at fixed fault pressure.
//!
//! Run: `cargo bench --bench bench_faults`

use std::sync::Arc;

use pipesim::coordinator::{
    fit_params, ArrivalSpec, Experiment, ExperimentConfig, ExperimentResult, StrategySpec,
};
use pipesim::des::DAY;
use pipesim::empirical::GroundTruth;
use pipesim::model::{FaultModel, TaskFaultConfig};
use pipesim::runtime::Runtime;
use pipesim::util::bench::Bench;
use pipesim::util::Json;

/// The shared 7-day saturated workload; `faults` is the only knob.
fn cfg(name: &str, faults: Option<FaultModel>) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        name: name.into(),
        seed: 2,
        horizon: 7.0 * DAY,
        arrival: ArrivalSpec::Profile,
        record_traces: false,
        ..Default::default()
    };
    cfg.infra.training_capacity = 4;
    cfg.infra.faults = faults;
    cfg
}

fn faulting(mean_time_to_fault: f64, retry: StrategySpec) -> Option<FaultModel> {
    let mut fm = FaultModel::uniform(TaskFaultConfig::transient(mean_time_to_fault));
    fm.retry = retry;
    Some(fm)
}

fn row(label: &str, r: &ExperimentResult, events_per_sec: f64) -> Json {
    Json::obj(vec![
        ("name", Json::Str(label.into())),
        ("events_per_sec", Json::Num(events_per_sec)),
        ("task_faults", Json::Num(r.task_faults as f64)),
        ("retries", Json::Num(r.retries as f64)),
        ("abandoned", Json::Num(r.abandoned as f64)),
        ("shed", Json::Num(r.shed as f64)),
        ("wasted_work_s", Json::Num(r.wasted_work)),
        ("deadline_attainment", Json::Num(r.deadline_attainment)),
        ("mean_wait_training_s", Json::Num(r.wait_training.mean())),
        ("completed", Json::Num(r.completed as f64)),
    ])
}

fn main() {
    let db = GroundTruth::new(17).generate_weeks(4);
    let runtime = Runtime::load_default().map(Arc::new);
    let backend = if runtime.is_some() { "pjrt" } else { "cpu" };
    let params = Arc::new(fit_params(&db, runtime.clone()).expect("fit"));
    let mut b = Bench::with_budget(std::time::Duration::from_millis(100), 3);

    let mut run = |b: &mut Bench, label: &str, c: ExperimentConfig| {
        let mut out = None;
        let m = b
            .bench_once(format!("7-day run [{label}]"), || {
                out = Some(
                    Experiment::new(c.clone(), params.clone())
                        .with_runtime(runtime.clone())
                        .run()
                        .expect("run"),
                );
            })
            .clone();
        let r = out.unwrap();
        let eps = r.events_processed as f64 / m.min.as_secs_f64();
        (r, eps)
    };

    // -- claim 1: the fault-off path costs nothing --------------------
    println!("# fault-off overhead (baseline vs inert model, 7 days)");
    let (base, base_eps) = run(&mut b, "no fault model", cfg("base", None));
    let (inert, inert_eps) = run(
        &mut b,
        "inert model (mttf >> any attempt)",
        cfg("inert", faulting(1e30, StrategySpec::new("exp_backoff"))),
    );
    assert_eq!(
        base.digest(),
        inert.digest(),
        "inert fault model changed outcomes"
    );
    assert_eq!(inert.task_faults, 0, "inert model must never fire");
    assert_eq!(inert.retries, 0);
    assert_eq!(inert.wasted_work, 0.0);
    let overhead = base_eps / inert_eps - 1.0;
    println!(
        "events/s: {base_eps:.0} (off) vs {inert_eps:.0} (inert), overhead {:+.2}%",
        100.0 * overhead
    );
    // digest equality already proves identical work; the wall-clock
    // guard is deliberately loose (shared CI runners are noisy)
    assert!(
        overhead < 0.5,
        "fault-off path overhead is not near-zero: {:+.1}%",
        100.0 * overhead
    );

    // -- claim 2: throughput under fault pressure ---------------------
    println!("# fault-rate ablation (exp_backoff retry)");
    println!("mttf_s,events_per_sec,task_faults,retries,abandoned,wasted_work_s,completed");
    let mut rate_rows = vec![
        row("off", &base, base_eps),
        row("inert", &inert, inert_eps),
    ];
    for mttf in [14_400.0, 3600.0, 1200.0] {
        let (r, eps) = run(
            &mut b,
            &format!("mttf {mttf}s"),
            cfg(
                &format!("mttf{mttf}"),
                faulting(mttf, StrategySpec::new("exp_backoff")),
            ),
        );
        assert!(r.task_faults > 0, "7 days at mttf {mttf}s must fault");
        assert_eq!(
            r.arrived,
            r.completed + r.abandoned + r.shed + r.in_flight,
            "conservation"
        );
        println!(
            "{mttf},{eps:.0},{},{},{},{:.0},{}",
            r.task_faults, r.retries, r.abandoned, r.wasted_work, r.completed
        );
        rate_rows.push(row(&format!("mttf{mttf}"), &r, eps));
    }

    // -- claim 3: retry-policy trade-offs at fixed pressure -----------
    println!("# retry-policy ablation (mttf 3600s)");
    println!("policy,mean_wait_training_s,deadline_attainment,retries,abandoned,completed");
    let mut policy_rows = Vec::new();
    for policy in ["always", "fixed", "exp_backoff", "deadline_aware"] {
        let (r, eps) = run(
            &mut b,
            &format!("retry {policy}"),
            cfg(
                &format!("re-{policy}"),
                faulting(3600.0, StrategySpec::new(policy)),
            ),
        );
        assert_eq!(
            r.arrived,
            r.completed + r.abandoned + r.shed + r.in_flight,
            "conservation under {policy}"
        );
        println!(
            "{policy},{:.1},{:.4},{},{},{}",
            r.wait_training.mean(),
            r.deadline_attainment,
            r.retries,
            r.abandoned,
            r.completed
        );
        policy_rows.push(row(policy, &r, eps));
    }

    let json = Json::obj(vec![
        ("bench", Json::Str("faults".into())),
        ("backend", Json::Str(backend.into())),
        ("overhead_off_path", Json::Num(overhead)),
        ("fault_rate", Json::Arr(rate_rows)),
        ("retry_policy", Json::Arr(policy_rows)),
    ]);
    std::fs::write("BENCH_faults.json", json.to_string()).expect("write BENCH_faults.json");
    println!("# wrote BENCH_faults.json");
}
