//! Heterogeneous-hardware perf + placement ablation: what the hw-class
//! subsystem costs when its layout is degenerate, how the placement
//! strategies trade wait against cost on a mixed fleet, and how the
//! class-aware grant path scales with class count.
//!
//! Three claims tracked across PRs via `BENCH_placement.json`:
//!   1. degenerate-layout overhead is zero in work terms — one class at
//!      speed 1.0 with no cost knobs is digest-identical to the
//!      homogeneous pool, and its wall-clock stays within noise;
//!   2. `fastest_fit` and `cheapest_fit` demonstrably diverge on a
//!      fast-expensive + slow-cheap fleet (wait/cost rows per placer);
//!   3. splitting a fixed capacity into more classes keeps the event
//!      stream byte-identical (speed 1.0 everywhere) while the per-grant
//!      placement cost grows only mildly with class count.
//!
//! Run: `cargo bench --bench bench_placement`

use std::sync::Arc;

use pipesim::coordinator::{
    fit_params, ArrivalSpec, Experiment, ExperimentConfig, ExperimentResult, StrategySpec,
};
use pipesim::des::DAY;
use pipesim::empirical::GroundTruth;
use pipesim::model::{HwClass, HwClasses};
use pipesim::runtime::Runtime;
use pipesim::util::bench::Bench;
use pipesim::util::Json;

/// The shared 7-day workload; the class layout is the only knob. The
/// training capacity snaps to the class slot sum so every cell compares
/// like against like.
fn cfg(name: &str, classes: Option<HwClasses>) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        name: name.into(),
        seed: 2,
        horizon: 7.0 * DAY,
        arrival: ArrivalSpec::Profile,
        record_traces: false,
        ..Default::default()
    };
    cfg.infra.training_capacity = 4;
    if let Some(hw) = classes {
        let total: usize = hw.training.iter().map(|c| c.slots).sum();
        if total > 0 {
            cfg.infra.training_capacity = total;
        }
        cfg.infra.hw_classes = Some(hw);
    }
    cfg
}

fn hw(training: Vec<HwClass>, placer: &str) -> HwClasses {
    HwClasses {
        training,
        compute: Vec::new(),
        placer: StrategySpec::new(placer),
    }
}

fn row(label: &str, r: &ExperimentResult, events_per_sec: f64) -> Json {
    Json::obj(vec![
        ("name", Json::Str(label.into())),
        ("events_per_sec", Json::Num(events_per_sec)),
        ("mean_wait_training_s", Json::Num(r.wait_training.mean())),
        ("util_training", Json::Num(r.util_training)),
        ("cost", Json::Num(r.cost)),
        ("completed", Json::Num(r.completed as f64)),
    ])
}

fn main() {
    let db = GroundTruth::new(17).generate_weeks(4);
    let runtime = Runtime::load_default().map(Arc::new);
    let backend = if runtime.is_some() { "pjrt" } else { "cpu" };
    let params = Arc::new(fit_params(&db, runtime.clone()).expect("fit"));
    let mut b = Bench::with_budget(std::time::Duration::from_millis(100), 3);

    let mut run = |b: &mut Bench, label: &str, c: ExperimentConfig| {
        let mut out = None;
        let m = b
            .bench_once(format!("7-day run [{label}]"), || {
                out = Some(
                    Experiment::new(c.clone(), params.clone())
                        .with_runtime(runtime.clone())
                        .run()
                        .expect("run"),
                );
            })
            .clone();
        let r = out.unwrap();
        let eps = r.events_processed as f64 / m.min.as_secs_f64();
        (r, eps)
    };

    // -- claim 1: the degenerate class layout costs nothing -----------
    println!("# degenerate-layout overhead (homogeneous vs one class at speed 1.0)");
    let (base, base_eps) = run(&mut b, "homogeneous pool", cfg("base", None));
    let (one, one_eps) = run(
        &mut b,
        "one class, speed 1.0",
        cfg("one-class", Some(hw(vec![HwClass::new("only", 4)], "fastest_fit"))),
    );
    assert_eq!(
        base.digest(),
        one.digest(),
        "a degenerate single class changed outcomes"
    );
    let overhead = base_eps / one_eps - 1.0;
    println!(
        "events/s: {base_eps:.0} (homogeneous) vs {one_eps:.0} (one class), overhead {:+.2}%",
        100.0 * overhead
    );
    // digest equality already proves identical work; the wall-clock
    // guard is deliberately loose (shared CI runners are noisy)
    assert!(
        overhead < 0.5,
        "degenerate class layout overhead is not near-zero: {:+.1}%",
        100.0 * overhead
    );

    // -- claim 2: placer ablation on a mixed fleet --------------------
    // moderate load so more than one class usually has free slots —
    // placement is only a choice when the cluster has slack
    println!("# placer ablation (a100 1x speed 2.0 $0.004/s + k80 3x speed 1.0 $0.001/s)");
    println!("placer,events_per_sec,mean_wait_training_s,cost,completed");
    let fleet = |placer: &str| {
        hw(
            vec![
                HwClass::new("a100", 1).with_speed(2.0).with_cost(0.004),
                HwClass::new("k80", 3).with_cost(0.001),
            ],
            placer,
        )
    };
    let mut placer_rows = Vec::new();
    let mut by_name: Vec<(String, ExperimentResult)> = Vec::new();
    for placer in ["fastest_fit", "cheapest_fit", "pack", "spread"] {
        let mut c = cfg(&format!("pl-{placer}"), Some(fleet(placer)));
        c.arrival = ArrivalSpec::Poisson {
            mean_interarrival: 240.0,
        };
        let (r, eps) = run(&mut b, placer, c);
        assert_eq!(r.arrived, r.completed + r.in_flight, "{placer}: conservation");
        assert!(r.cost > 0.0, "{placer}: priced fleet accrued no cost");
        println!(
            "{placer},{eps:.0},{:.1},{:.2},{}",
            r.wait_training.mean(),
            r.cost,
            r.completed
        );
        placer_rows.push(row(placer, &r, eps));
        by_name.push((placer.into(), r));
    }
    let get = |n: &str| &by_name.iter().find(|(p, _)| p == n).unwrap().1;
    let (fast, cheap) = (get("fastest_fit"), get("cheapest_fit"));
    assert_ne!(
        fast.digest(),
        cheap.digest(),
        "fastest_fit and cheapest_fit agreed on a heterogeneous fleet"
    );
    assert!(
        (fast.cost - cheap.cost).abs() > f64::EPSILON,
        "placement strategy did not move cost"
    );

    // -- claim 3: class-count scaling at fixed capacity ---------------
    // identical speed-1.0 classes: any split of the same 8 slots must
    // replay the homogeneous event stream byte-for-byte, so this row
    // isolates the pure bookkeeping cost of the class-aware grant path
    println!("# class-count scaling (8 slots, all classes speed 1.0)");
    println!("classes,events_per_sec");
    let mut wide = cfg("wide-base", None);
    wide.infra.training_capacity = 8;
    let (wide_base, wide_eps) = run(&mut b, "8 slots, homogeneous", wide);
    let mut scale_rows = vec![Json::obj(vec![
        ("classes", Json::Num(0.0)),
        ("events_per_sec", Json::Num(wide_eps)),
    ])];
    println!("0,{wide_eps:.0}");
    for n in [1usize, 2, 4, 8] {
        let classes: Vec<HwClass> = (0..n)
            .map(|i| HwClass::new(format!("c{i}"), 8 / n))
            .collect();
        let (r, eps) = run(
            &mut b,
            &format!("{n} classes"),
            cfg(&format!("split{n}"), Some(hw(classes, "spread"))),
        );
        assert_eq!(
            wide_base.digest(),
            r.digest(),
            "splitting 8 speed-1.0 slots into {n} classes changed outcomes"
        );
        println!("{n},{eps:.0}");
        scale_rows.push(Json::obj(vec![
            ("classes", Json::Num(n as f64)),
            ("events_per_sec", Json::Num(eps)),
        ]));
    }

    let json = Json::obj(vec![
        ("bench", Json::Str("placement".into())),
        ("backend", Json::Str(backend.into())),
        ("overhead_degenerate_layout", Json::Num(overhead)),
        ("placers", Json::Arr(placer_rows)),
        ("class_scaling", Json::Arr(scale_rows)),
    ]);
    std::fs::write("BENCH_placement.json", json.to_string()).expect("write BENCH_placement.json");
    println!("# wrote BENCH_placement.json");
}
