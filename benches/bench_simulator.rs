//! Fig 13 reproduction bench: end-to-end simulator throughput vs number
//! of pipeline executions (wall-clock + µs/pipeline + memory), plus the
//! paper's headline configuration (44 s mean interarrival).
//!
//! Emits `BENCH_simulator.json` (events/sec, µs/pipeline, peak RSS at
//! the 100k-pipeline scale) so the single-thread perf trajectory is
//! tracked across PRs. Run: `cargo bench --bench bench_simulator`

use std::sync::Arc;

use pipesim::coordinator::{fit_params, ArrivalSpec, Experiment, ExperimentConfig};
use pipesim::empirical::GroundTruth;
use pipesim::runtime::Runtime;
use pipesim::util::bench::Bench;
use pipesim::util::Json;

fn main() {
    let db = GroundTruth::new(5).generate_weeks(4);
    let runtime = Runtime::load_default().map(Arc::new);
    let backend = if runtime.is_some() { "pjrt" } else { "cpu" };
    println!("# sampler backend: {backend}");
    let params = fit_params(&db, runtime.clone()).expect("fit");

    let mut b = Bench::with_budget(std::time::Duration::from_millis(200), 3);

    println!("# Fig 13: wall-clock vs #pipelines (flat 44 s interarrival)");
    println!("pipelines,wall_secs,us_per_pipeline,events_per_sec,peak_rss_mb");
    let mut headline = None;
    for n in [1_000u64, 10_000, 100_000] {
        let mut last = None;
        b.bench_once(format!("simulate {n} pipelines"), || {
            let cfg = ExperimentConfig {
                name: format!("bench-{n}"),
                seed: 1,
                horizon: f64::MAX / 4.0,
                arrival: ArrivalSpec::Poisson {
                    mean_interarrival: 44.0,
                },
                max_pipelines: Some(n),
                record_traces: false,
                sample_interval: 3600.0,
                ..Default::default()
            };
            let r = Experiment::new(cfg, params.clone())
                .with_runtime(runtime.clone())
                .run()
                .expect("run");
            last = Some((r.wall_secs, r.us_per_pipeline(), r.events_per_sec(), r.peak_rss_mb));
        });
        let (w, us, eps, rss) = last.unwrap();
        println!("{n},{w:.4},{us:.2},{eps:.0},{rss:.1}");
        if n == 100_000 {
            headline = Some((w, us, eps, rss));
        }
    }

    // trace recording cost (the tsdb substrate's overhead, cf. the
    // paper's InfluxDB pain)
    let mut traced_eps = 0.0;
    for record in [false, true] {
        b.bench_once(format!("simulate 50k pipelines, traces={record}"), || {
            let cfg = ExperimentConfig {
                name: "bench-traces".into(),
                seed: 1,
                horizon: f64::MAX / 4.0,
                arrival: ArrivalSpec::Poisson {
                    mean_interarrival: 44.0,
                },
                max_pipelines: Some(50_000),
                record_traces: record,
                sample_interval: 3600.0,
                ..Default::default()
            };
            let r = Experiment::new(cfg, params.clone())
                .with_runtime(runtime.clone())
                .run()
                .expect("run");
            if record {
                traced_eps = r.events_per_sec();
            }
        });
    }

    let (wall, us, eps, rss) = headline.expect("100k row measured");
    let json = Json::obj(vec![
        ("bench", Json::Str("simulator".into())),
        ("backend", Json::Str(backend.into())),
        ("pipelines", Json::Num(100_000.0)),
        ("wall_secs", Json::Num(wall)),
        ("us_per_pipeline", Json::Num(us)),
        ("events_per_sec", Json::Num(eps)),
        ("events_per_sec_traced_50k", Json::Num(traced_eps)),
        ("peak_rss_mb", Json::Num(rss)),
    ]);
    std::fs::write("BENCH_simulator.json", json.to_string()).expect("write BENCH_simulator.json");
    println!("# wrote BENCH_simulator.json ({eps:.0} events/s single-thread)");
}
