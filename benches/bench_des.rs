//! DES-core microbenchmarks: calendar throughput and resource cycling.
//!
//! These bound the simulator's event-loop cost (the denominator of the
//! Fig 13 headline). Run: `cargo bench --bench bench_des`

use pipesim::des::{Calendar, JobCtx, Resource};
use pipesim::stats::rng::Pcg64;
use pipesim::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new();

    // schedule+pop cycle on a queue kept at depth ~1000
    let mut cal: Calendar<u64> = Calendar::new();
    let mut rng = Pcg64::new(1);
    for i in 0..1000 {
        cal.schedule(rng.uniform() * 1e6, i);
    }
    let mut i = 1000u64;
    b.bench("calendar schedule+pop (depth 1000)", || {
        let (t, v) = cal.pop().unwrap();
        black_box(v);
        cal.schedule_at(t + rng.uniform() * 1e6, i);
        i += 1;
    });

    // deep calendar
    let mut cal2: Calendar<u64> = Calendar::new();
    for i in 0..100_000 {
        cal2.schedule(rng.uniform() * 1e9, i);
    }
    let mut j = 100_000u64;
    b.bench("calendar schedule+pop (depth 100k)", || {
        let (t, v) = cal2.pop().unwrap();
        black_box(v);
        cal2.schedule_at(t + rng.uniform() * 1e9, j);
        j += 1;
    });

    // resource request/release with queueing (capacity 10, 20 in flight)
    let mut res: Resource<u32> = Resource::new("bench", 10);
    let mut t = 0.0f64;
    for k in 0..20 {
        res.request(t, k, JobCtx::new(1.0, 1.0, t));
    }
    b.bench("resource release+request (contended)", || {
        t += 1.0;
        black_box(res.release(t));
        res.request(t, 99, JobCtx::new(1.0, 1.0, t));
    });

    // uncontended fast path
    let mut res2: Resource<u32> = Resource::new("bench2", 1_000_000);
    let mut t2 = 0.0f64;
    b.bench("resource request+release (uncontended)", || {
        t2 += 1.0;
        res2.request(t2, 1, JobCtx::new(0.0, 0.0, t2));
        black_box(res2.release(t2));
    });

    // RNG primitives feeding the simulator
    let mut r = Pcg64::new(2);
    b.bench("pcg64 normal()", || {
        black_box(r.normal());
    });
    b.bench("pcg64 uniform()", || {
        black_box(r.uniform());
    });
}
