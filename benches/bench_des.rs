//! DES-core microbenchmarks: calendar throughput (with and without
//! event cancellation), resource cycling, deep-queue grant scaling, and
//! RNG primitives.
//!
//! These bound the simulator's event-loop cost (the denominator of the
//! Fig 13 headline). The cancellation cases guard the PR 4 claim that
//! cancellable events leave the zero-cancellation hot path unperturbed
//! (asserted via the tombstone counters). The deep-queue cases pin the
//! indexed-waiter-heap claim: draining a queue of Q waiters costs
//! O(Q log Q) total, so 10× the depth must grow the total grant cost by
//! ~10–13×, not the ~100× of the old linear argmin scan — asserted
//! here, recorded in `BENCH_des.json` for the CI perf snapshot.
//!
//! Run: `cargo bench --bench bench_des`

use std::time::Instant;

use pipesim::des::{Calendar, JobCtx, Resource};
use pipesim::stats::rng::Pcg64;
use pipesim::util::bench::{black_box, Bench};
use pipesim::util::Json;

/// Seconds to drain a capacity-1 priority resource with `q` queued
/// waiters (one `release` per grant — each pops the heap minimum).
/// Queue build-up is untimed; best of `reps` drains.
fn drain_deep_queue(q: usize, reps: usize) -> f64 {
    use pipesim::coordinator::{build_scheduler, StrategySpec};
    let mut best = f64::INFINITY;
    for rep in 0..reps {
        let mut rng = Pcg64::new(0xDEE9 + rep as u64);
        let mut res: Resource<u32> = Resource::with_scheduler(
            "deep",
            1,
            build_scheduler(&StrategySpec::new("priority")).unwrap(),
        );
        res.request(0.0, u32::MAX, JobCtx::new(1.0, 1.0, 0.0));
        for i in 0..q as u32 {
            // heavy key ties so the seq tie-break is exercised at depth
            let pri = rng.below(16) as f64;
            res.request(i as f64, i, JobCtx::new(1.0, pri, i as f64));
        }
        let t0 = Instant::now();
        let mut t = q as f64;
        for _ in 0..q {
            t += 1.0;
            black_box(res.release(t).expect("waiter available"));
        }
        best = best.min(t0.elapsed().as_secs_f64());
        assert_eq!(res.queued(), 0);
    }
    best
}

/// Mean of the most recent measurement, in nanoseconds per iteration.
fn last_ns(b: &Bench) -> f64 {
    b.results().last().expect("measured").mean.as_secs_f64() * 1e9
}

fn main() {
    let mut b = Bench::new();
    let mut rows: Vec<(&'static str, f64)> = Vec::new();

    // schedule+pop cycle on a queue kept at depth ~1000, no cancellation
    let mut cal: Calendar<u64> = Calendar::new();
    let mut rng = Pcg64::new(1);
    for i in 0..1000 {
        cal.schedule(rng.uniform() * 1e6, i);
    }
    let mut i = 1000u64;
    b.bench("calendar schedule+pop (depth 1000)", || {
        let (t, v) = cal.pop().unwrap();
        black_box(v);
        cal.schedule_at(t + rng.uniform() * 1e6, i);
        i += 1;
    });
    rows.push(("calendar_cycle_ns", last_ns(&b)));
    // the zero-cancellation run must never have engaged the tombstone
    // machinery: the PR 1 heap hot path is intact
    assert_eq!(cal.cancelled_total(), 0, "zero-cancel bench touched cancel");
    assert_eq!(cal.tombstones(), 0);

    // same cycle with ~10% of scheduled events cancelled before firing
    let mut cal_c: Calendar<u64> = Calendar::new();
    for i in 0..1000 {
        cal_c.schedule(rng.uniform() * 1e6, i);
    }
    let mut j = 1000u64;
    b.bench("calendar schedule+pop (depth 1000, 10% cancelled)", || {
        let (t, v) = cal_c.pop().unwrap();
        black_box(v);
        let h = cal_c.schedule_at(t + rng.uniform() * 1e6, j);
        if j % 10 == 0 {
            // cancel the pending event and replace it so depth holds
            if cal_c.cancel(h) {
                cal_c.schedule_at(t + rng.uniform() * 1e6, j);
            }
        }
        j += 1;
    });
    rows.push(("calendar_cycle_10pct_cancel_ns", last_ns(&b)));
    assert!(cal_c.cancelled_total() > 0, "cancel bench never cancelled");

    // deep calendar
    let mut cal2: Calendar<u64> = Calendar::new();
    for i in 0..100_000 {
        cal2.schedule(rng.uniform() * 1e9, i);
    }
    let mut k = 100_000u64;
    b.bench("calendar schedule+pop (depth 100k)", || {
        let (t, v) = cal2.pop().unwrap();
        black_box(v);
        cal2.schedule_at(t + rng.uniform() * 1e9, k);
        k += 1;
    });
    rows.push(("calendar_cycle_deep_ns", last_ns(&b)));

    // resource request/release with queueing (capacity 10, 20 in flight)
    let mut res: Resource<u32> = Resource::new("bench", 10);
    let mut t = 0.0f64;
    for n in 0..20 {
        res.request(t, n, JobCtx::new(1.0, 1.0, t));
    }
    b.bench("resource release+request (contended)", || {
        t += 1.0;
        black_box(res.release(t));
        res.request(t, 99, JobCtx::new(1.0, 1.0, t));
    });
    rows.push(("resource_contended_ns", last_ns(&b)));

    // deep-queue grant scaling: the indexed-heap acceptance case. A
    // persistently overloaded cell grows its queue with sim time; with
    // the heap, draining Q waiters is O(Q log Q) total, so 10× depth
    // grows the drain ~10–13×. The old linear scan was O(Q²): ~100×.
    let q1 = 1_000usize;
    let q10 = 10_000usize;
    let drain_1k = drain_deep_queue(q1, 5);
    let drain_10k = drain_deep_queue(q10, 5);
    let scaling = drain_10k / drain_1k.max(1e-12);
    println!(
        "# deep queue: drain {q1} = {:.3} ms ({:.0} ns/grant), drain {q10} = {:.3} ms \
         ({:.0} ns/grant), 10x-depth total-cost ratio {scaling:.1}x",
        drain_1k * 1e3,
        drain_1k * 1e9 / q1 as f64,
        drain_10k * 1e3,
        drain_10k * 1e9 / q10 as f64
    );
    assert!(
        scaling <= 15.0,
        "deep-queue grant cost scales super-linearithmically: 10x depth cost {scaling:.1}x \
         (linear-scan regression?)"
    );
    rows.push(("deep_queue_grant_q1k_ns", drain_1k * 1e9 / q1 as f64));
    rows.push(("deep_queue_grant_q10k_ns", drain_10k * 1e9 / q10 as f64));
    rows.push(("deep_queue_scaling_10x", scaling));

    // uncontended fast path
    let mut res2: Resource<u32> = Resource::new("bench2", 1_000_000);
    let mut t2 = 0.0f64;
    b.bench("resource request+release (uncontended)", || {
        t2 += 1.0;
        res2.request(t2, 1, JobCtx::new(0.0, 0.0, t2));
        black_box(res2.release(t2));
    });
    rows.push(("resource_uncontended_ns", last_ns(&b)));

    // RNG primitives feeding the simulator
    let mut r = Pcg64::new(2);
    b.bench("pcg64 normal()", || {
        black_box(r.normal());
    });
    b.bench("pcg64 uniform()", || {
        black_box(r.uniform());
    });

    let cases: Vec<(String, Json)> = rows
        .iter()
        .map(|(key, v)| (key.to_string(), Json::Num(*v)))
        .collect();
    let json = Json::obj(vec![
        ("bench", Json::Str("des".into())),
        ("cases", Json::Obj(cases)),
    ]);
    std::fs::write("BENCH_des.json", json.to_string()).expect("write BENCH_des.json");
    println!("# wrote BENCH_des.json");
}
