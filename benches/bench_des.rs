//! DES-core microbenchmarks: calendar throughput (with and without
//! event cancellation), resource cycling, and RNG primitives.
//!
//! These bound the simulator's event-loop cost (the denominator of the
//! Fig 13 headline). The cancellation cases guard the tentpole claim
//! that cancellable events leave the zero-cancellation hot path
//! unperturbed: the zero-cancel cycle is measured on a calendar that
//! has the cancellation machinery but never uses it (asserted via the
//! tombstone counters), side by side with a 10%-cancellation cycle.
//! Emits `BENCH_des.json` for the CI perf snapshot.
//!
//! Run: `cargo bench --bench bench_des`

use pipesim::des::{Calendar, JobCtx, Resource};
use pipesim::stats::rng::Pcg64;
use pipesim::util::bench::{black_box, Bench};
use pipesim::util::Json;

/// Mean of the most recent measurement, in nanoseconds per iteration.
fn last_ns(b: &Bench) -> f64 {
    b.results().last().expect("measured").mean.as_secs_f64() * 1e9
}

fn main() {
    let mut b = Bench::new();
    let mut rows: Vec<(&'static str, f64)> = Vec::new();

    // schedule+pop cycle on a queue kept at depth ~1000, no cancellation
    let mut cal: Calendar<u64> = Calendar::new();
    let mut rng = Pcg64::new(1);
    for i in 0..1000 {
        cal.schedule(rng.uniform() * 1e6, i);
    }
    let mut i = 1000u64;
    b.bench("calendar schedule+pop (depth 1000)", || {
        let (t, v) = cal.pop().unwrap();
        black_box(v);
        cal.schedule_at(t + rng.uniform() * 1e6, i);
        i += 1;
    });
    rows.push(("calendar_cycle_ns", last_ns(&b)));
    // the zero-cancellation run must never have engaged the tombstone
    // machinery: the PR 1 heap hot path is intact
    assert_eq!(cal.cancelled_total(), 0, "zero-cancel bench touched cancel");
    assert_eq!(cal.tombstones(), 0);

    // same cycle with ~10% of scheduled events cancelled before firing
    let mut cal_c: Calendar<u64> = Calendar::new();
    for i in 0..1000 {
        cal_c.schedule(rng.uniform() * 1e6, i);
    }
    let mut j = 1000u64;
    b.bench("calendar schedule+pop (depth 1000, 10% cancelled)", || {
        let (t, v) = cal_c.pop().unwrap();
        black_box(v);
        let h = cal_c.schedule_at(t + rng.uniform() * 1e6, j);
        if j % 10 == 0 {
            // cancel the pending event and replace it so depth holds
            if cal_c.cancel(h) {
                cal_c.schedule_at(t + rng.uniform() * 1e6, j);
            }
        }
        j += 1;
    });
    rows.push(("calendar_cycle_10pct_cancel_ns", last_ns(&b)));
    assert!(cal_c.cancelled_total() > 0, "cancel bench never cancelled");

    // deep calendar
    let mut cal2: Calendar<u64> = Calendar::new();
    for i in 0..100_000 {
        cal2.schedule(rng.uniform() * 1e9, i);
    }
    let mut k = 100_000u64;
    b.bench("calendar schedule+pop (depth 100k)", || {
        let (t, v) = cal2.pop().unwrap();
        black_box(v);
        cal2.schedule_at(t + rng.uniform() * 1e9, k);
        k += 1;
    });
    rows.push(("calendar_cycle_deep_ns", last_ns(&b)));

    // resource request/release with queueing (capacity 10, 20 in flight)
    let mut res: Resource<u32> = Resource::new("bench", 10);
    let mut t = 0.0f64;
    for n in 0..20 {
        res.request(t, n, JobCtx::new(1.0, 1.0, t));
    }
    b.bench("resource release+request (contended)", || {
        t += 1.0;
        black_box(res.release(t));
        res.request(t, 99, JobCtx::new(1.0, 1.0, t));
    });
    rows.push(("resource_contended_ns", last_ns(&b)));

    // uncontended fast path
    let mut res2: Resource<u32> = Resource::new("bench2", 1_000_000);
    let mut t2 = 0.0f64;
    b.bench("resource request+release (uncontended)", || {
        t2 += 1.0;
        res2.request(t2, 1, JobCtx::new(0.0, 0.0, t2));
        black_box(res2.release(t2));
    });
    rows.push(("resource_uncontended_ns", last_ns(&b)));

    // RNG primitives feeding the simulator
    let mut r = Pcg64::new(2);
    b.bench("pcg64 normal()", || {
        black_box(r.normal());
    });
    b.bench("pcg64 uniform()", || {
        black_box(r.uniform());
    });

    let cases: Vec<(String, Json)> = rows
        .iter()
        .map(|(key, v)| (key.to_string(), Json::Num(*v)))
        .collect();
    let json = Json::obj(vec![
        ("bench", Json::Str("des".into())),
        ("cases", Json::Obj(cases)),
    ]);
    std::fs::write("BENCH_des.json", json.to_string()).expect("write BENCH_des.json");
    println!("# wrote BENCH_des.json");
}
