//! Observability-layer benchmarks: meter-off overhead (with a hard
//! digest-equality guard — the probe must be invisible when disabled),
//! meter-on overhead, downsampled-vs-raw tsdb memory at 1M points (with
//! a hard bound on the downsampled footprint), and exporter throughput.
//!
//! Emits `BENCH_obs.json` for the CI perf trajectory.
//!
//! Run: `cargo bench --bench bench_obs`

use pipesim::coordinator::{
    fit_params, ArrivalSpec, Experiment, ExperimentConfig, RetentionConfig,
};
use pipesim::des::DAY;
use pipesim::empirical::GroundTruth;
use pipesim::obs::{render_metrics_json, render_openmetrics};
use pipesim::tsdb::{SeriesKey, TsStore};
use pipesim::util::bench::{black_box, Bench};
use pipesim::util::Json;

fn main() {
    let db = GroundTruth::new(29).generate_weeks(2);
    let params = fit_params(&db, None).expect("fit");
    let mut b = Bench::with_budget(std::time::Duration::from_millis(200), 3);
    let mut report: Vec<(&str, Json)> = vec![("bench", Json::Str("obs".into()))];

    // --- meter overhead: off must be free, on must be cheap ------------
    let run = |meter: bool| {
        let cfg = ExperimentConfig {
            name: "meter-bench".into(),
            seed: 7,
            horizon: 2.0 * DAY,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 60.0,
            },
            record_traces: false,
            meter,
            ..Default::default()
        };
        Experiment::new(cfg, params.clone()).run().expect("run")
    };
    let mut off_secs = 0.0;
    let mut off_digest = String::new();
    b.bench_once("2-day run, meter off", || {
        let r = run(false);
        off_secs = r.wall_secs;
        off_digest = r.digest();
    });
    let mut on_secs = 0.0;
    let mut metered = None;
    b.bench_once("2-day run, meter on", || {
        let r = run(true);
        on_secs = r.wall_secs;
        metered = Some(r);
    });
    let metered = metered.expect("metered run");
    // the hard guard: metering must not perturb the simulation
    assert_eq!(
        off_digest,
        metered.digest(),
        "meter-on digest must equal meter-off"
    );
    let m = metered.meter.as_ref().expect("meter report");
    assert_eq!(m.total_events(), metered.events_processed);
    let overhead_pct = if off_secs > 0.0 {
        100.0 * (on_secs / off_secs - 1.0)
    } else {
        0.0
    };
    println!(
        "# meter overhead: {overhead_pct:.1}% over {} events (loop wall {:.3}s)",
        metered.events_processed,
        m.loop_wall_secs()
    );
    report.push(("meter_overhead_pct", Json::Num(overhead_pct)));
    report.push(("events", Json::Num(metered.events_processed as f64)));

    // --- downsampled vs raw tsdb at 1M points --------------------------
    {
        let n = 1_000_000u64;
        let resolution = 3600.0;
        let mut raw = TsStore::new();
        let hr = raw.handle(SeriesKey::new("m").tag("k", "v"));
        let t0 = std::time::Instant::now();
        for i in 0..n {
            raw.append(hr, i as f64, (i % 1000) as f64);
        }
        let raw_append_eps = n as f64 / t0.elapsed().as_secs_f64().max(1e-12);
        let mut rolled = TsStore::new();
        rolled.set_retention(resolution);
        let hd = rolled.handle(SeriesKey::new("m").tag("k", "v"));
        let t0 = std::time::Instant::now();
        for i in 0..n {
            rolled.append(hd, i as f64, (i % 1000) as f64);
        }
        let rolled_append_eps = n as f64 / t0.elapsed().as_secs_f64().max(1e-12);
        let raw_mb = raw.approx_bytes() as f64 / (1 << 20) as f64;
        let rolled_mb = rolled.approx_bytes() as f64 / (1 << 20) as f64;
        println!(
            "# tsdb 1M points: raw {raw_mb:.1} MB, downsampled {rolled_mb:.2} MB \
             ({} buckets); append raw {raw_append_eps:.0}/s, rolled {rolled_append_eps:.0}/s"
        , rolled.resident_points());
        // the memory-flat claim, as a hard bound: ~278 hour-buckets of
        // a bounded sketch each must stay under 2 MB (raw is ~15 MB)
        assert!(
            rolled.approx_bytes() < 2 << 20,
            "downsampled 1M-point store must stay bounded, got {} bytes",
            rolled.approx_bytes()
        );
        assert_eq!(rolled.num_points(), n as usize, "observed count invariant");
        report.push(("raw_1m_bytes", Json::Num(raw.approx_bytes() as f64)));
        report.push(("rolled_1m_bytes", Json::Num(rolled.approx_bytes() as f64)));
        report.push(("raw_append_per_sec", Json::Num(raw_append_eps)));
        report.push(("rolled_append_per_sec", Json::Num(rolled_append_eps)));
    }

    // --- exporter throughput -------------------------------------------
    let mut om_len = 0usize;
    let m = b
        .bench("render OpenMetrics", || {
            om_len = black_box(render_openmetrics(&metered)).len();
        })
        .clone();
    let om_mbps = om_len as f64 / (1 << 20) as f64 / m.mean.as_secs_f64().max(1e-12);
    let mut js_len = 0usize;
    let m = b
        .bench("render metrics JSON", || {
            js_len = black_box(render_metrics_json(&metered)).len();
        })
        .clone();
    let js_mbps = js_len as f64 / (1 << 20) as f64 / m.mean.as_secs_f64().max(1e-12);
    println!(
        "# exporters: openmetrics {om_len} B at {om_mbps:.1} MB/s, json {js_len} B at \
         {js_mbps:.1} MB/s"
    );
    report.push(("openmetrics_bytes", Json::Num(om_len as f64)));
    report.push(("openmetrics_mb_per_sec", Json::Num(om_mbps)));
    report.push(("json_bytes", Json::Num(js_len as f64)));
    report.push(("json_mb_per_sec", Json::Num(js_mbps)));

    // --- retention inside a real run: digest-neutral, memory down ------
    {
        let cfg = ExperimentConfig {
            name: "meter-bench".into(),
            seed: 7,
            horizon: 2.0 * DAY,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 60.0,
            },
            record_traces: false,
            retention: Some(RetentionConfig { resolution: 1800.0 }),
            ..Default::default()
        };
        let r = Experiment::new(cfg, params.clone()).run().expect("run");
        assert_eq!(off_digest, r.digest(), "retention must be digest-neutral");
        println!(
            "# retention run: {} resident vs {} raw points",
            r.tsdb.resident_points(),
            metered.tsdb.resident_points()
        );
        report.push(("retained_resident_points", Json::Num(r.tsdb.resident_points() as f64)));
        report.push(("raw_resident_points", Json::Num(metered.tsdb.resident_points() as f64)));
    }

    let json = Json::obj(report);
    std::fs::write("BENCH_obs.json", json.to_string()).expect("write BENCH_obs.json");
    println!("# wrote BENCH_obs.json");
}
