//! Trace-store bench: the substrate that replaces InfluxDB (which the
//! paper reports OOM-ing past a few hundred thousand pipelines, Fig 13
//! discussion). Measures hot-path appends and the dashboard queries.
//!
//! Run: `cargo bench --bench bench_tsdb`

use pipesim::stats::rng::Pcg64;
use pipesim::tsdb::{Agg, SeriesKey, TsStore};
use pipesim::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new();

    // hot-path append via interned handle
    let mut db = TsStore::new();
    let h = db.handle(SeriesKey::new("task_exec").tag("task", "train"));
    let mut t = 0.0f64;
    b.bench("append via handle", || {
        t += 1.0;
        db.append(h, t, 42.0);
    });

    // cold-path record (hash + intern each time)
    let mut db2 = TsStore::new();
    let mut t2 = 0.0f64;
    b.bench("record via key lookup", || {
        t2 += 1.0;
        db2.record(SeriesKey::new("util").tag("resource", "training"), t2, 0.5);
    });

    // build a realistic store: 3M points across 24 series
    let mut big = TsStore::new();
    let mut rng = Pcg64::new(1);
    let handles: Vec<_> = (0..24)
        .map(|i| big.handle(SeriesKey::new("m").tag("k", format!("{i}"))))
        .collect();
    for i in 0..3_000_000u64 {
        let h = handles[(i % 24) as usize];
        big.append(h, i as f64, rng.uniform());
    }
    println!(
        "# store: {} points, ~{} MB",
        big.num_points(),
        big.approx_bytes() / (1 << 20)
    );

    b.bench_once("window mean over 125k-point series", || {
        black_box(big.window(handles[0], 0.0, 3_000_000.0, 3600.0, Agg::Mean));
    });
    b.bench_once("window p95 over 125k-point series", || {
        black_box(big.window(handles[0], 0.0, 3_000_000.0, 3600.0, Agg::P95));
    });
    b.bench_once("group-by over 3M points / 24 groups", || {
        black_box(big.group_by("m", "k", 0.0, 3_000_000.0, 86_400.0, Agg::Mean));
    });
}
