//! Failure-injection perf + reliability ablation (DESIGN.md robustness
//! direction): what the failure subsystem costs when it is off, what it
//! costs when it is on, and how the checkpoint interval trades lost work
//! against goodput.
//!
//! Three claims tracked across PRs via `BENCH_failures.json`:
//!   1. failure-off overhead is zero in work terms — an inert failure
//!      model (MTBF far past the horizon) is digest-identical to no
//!      model at all, and its wall-clock stays within noise;
//!   2. failures-on throughput (events/s) degrades gracefully with
//!      failure pressure (MTBF sweep);
//!   3. tighter checkpoints monotonically recover goodput at fixed MTBF.
//!
//! Run: `cargo bench --bench bench_failures`

use std::sync::Arc;

use pipesim::coordinator::{
    fit_params, ArrivalSpec, Experiment, ExperimentConfig, ExperimentResult,
};
use pipesim::des::DAY;
use pipesim::empirical::GroundTruth;
use pipesim::model::{ClusterFailureConfig, FailureModel};
use pipesim::runtime::Runtime;
use pipesim::util::bench::Bench;
use pipesim::util::Json;

/// The shared 7-day saturated workload; `failures` is the only knob.
fn cfg(name: &str, failures: Option<FailureModel>) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        name: name.into(),
        seed: 2,
        horizon: 7.0 * DAY,
        arrival: ArrivalSpec::Profile,
        record_traces: false,
        ..Default::default()
    };
    cfg.infra.training_capacity = 4;
    cfg.infra.failures = failures;
    cfg
}

fn failing(mtbf: f64, ckpt: f64) -> Option<FailureModel> {
    Some(FailureModel {
        training: Some(
            ClusterFailureConfig::exponential(mtbf, 600.0).with_checkpointing(ckpt, 30.0),
        ),
        compute: None,
    })
}

fn row(label: &str, r: &ExperimentResult, events_per_sec: f64) -> Json {
    Json::obj(vec![
        ("name", Json::Str(label.into())),
        ("events_per_sec", Json::Num(events_per_sec)),
        ("failures", Json::Num(r.failures as f64)),
        ("repairs", Json::Num(r.repairs as f64)),
        ("lost_work_s", Json::Num(r.lost_work)),
        ("goodput", Json::Num(r.goodput)),
        ("completed", Json::Num(r.completed as f64)),
        ("recovery_p95_s", Json::Num(r.recovery_p95)),
    ])
}

fn main() {
    let db = GroundTruth::new(17).generate_weeks(4);
    let runtime = Runtime::load_default().map(Arc::new);
    let backend = if runtime.is_some() { "pjrt" } else { "cpu" };
    let params = Arc::new(fit_params(&db, runtime.clone()).expect("fit"));
    let mut b = Bench::with_budget(std::time::Duration::from_millis(100), 3);

    let mut run = |b: &mut Bench, label: &str, c: ExperimentConfig| {
        let mut out = None;
        let m = b
            .bench_once(format!("7-day run [{label}]"), || {
                out = Some(
                    Experiment::new(c.clone(), params.clone())
                        .with_runtime(runtime.clone())
                        .run()
                        .expect("run"),
                );
            })
            .clone();
        let r = out.unwrap();
        let eps = r.events_processed as f64 / m.min.as_secs_f64();
        (r, eps)
    };

    // -- claim 1: the failure-off path costs nothing ------------------
    println!("# failure-off overhead (baseline vs inert model, 7 days)");
    let (base, base_eps) = run(&mut b, "no failure model", cfg("base", None));
    let (inert, inert_eps) = run(
        &mut b,
        "inert model (mtbf >> horizon)",
        cfg("inert", failing(1e30, 600.0)),
    );
    assert_eq!(
        base.digest(),
        inert.digest(),
        "inert failure model changed outcomes"
    );
    assert_eq!(inert.failures, 0, "inert model must never fire");
    let overhead = base_eps / inert_eps - 1.0;
    println!(
        "events/s: {base_eps:.0} (off) vs {inert_eps:.0} (inert), overhead {:+.2}%",
        100.0 * overhead
    );
    // digest equality already proves identical work; the wall-clock
    // guard is deliberately loose (shared CI runners are noisy)
    assert!(
        overhead < 0.5,
        "failure-off path overhead is not near-zero: {:+.1}%",
        100.0 * overhead
    );

    // -- claim 2: throughput under failure pressure -------------------
    println!("# mtbf ablation (mttr 600s, checkpoint 600s, restart 30s)");
    println!("mtbf_s,events_per_sec,failures,repairs,lost_work_s,goodput,completed");
    let mut mtbf_rows = vec![
        row("off", &base, base_eps),
        row("inert", &inert, inert_eps),
    ];
    for mtbf in [14_400.0, 3600.0, 1200.0] {
        let (r, eps) = run(
            &mut b,
            &format!("mtbf {mtbf}s"),
            cfg(&format!("mtbf{mtbf}"), failing(mtbf, 600.0)),
        );
        assert!(r.failures > 0, "7 days at mtbf {mtbf}s must fail");
        assert_eq!(r.arrived, r.completed + r.in_flight, "conservation");
        println!(
            "{mtbf},{eps:.0},{},{},{:.0},{:.4},{}",
            r.failures, r.repairs, r.lost_work, r.goodput, r.completed
        );
        mtbf_rows.push(row(&format!("mtbf{mtbf}"), &r, eps));
    }

    // -- claim 3: checkpoint-interval tuning at fixed pressure --------
    println!("# checkpoint ablation (mtbf 3600s; 0 = checkpointing off)");
    println!("checkpoint_s,lost_work_s,goodput,completed");
    let mut ckpt_rows = Vec::new();
    for ckpt in [0.0, 3600.0, 600.0, 120.0] {
        let (r, eps) = run(
            &mut b,
            &format!("checkpoint {ckpt}s"),
            cfg(&format!("ckpt{ckpt}"), failing(3600.0, ckpt)),
        );
        println!("{ckpt},{:.0},{:.4},{}", r.lost_work, r.goodput, r.completed);
        ckpt_rows.push(row(&format!("ckpt{ckpt}"), &r, eps));
    }

    let json = Json::obj(vec![
        ("bench", Json::Str("failures".into())),
        ("backend", Json::Str(backend.into())),
        ("overhead_off_path", Json::Num(overhead)),
        ("mtbf", Json::Arr(mtbf_rows)),
        ("checkpoint", Json::Arr(ckpt_rows)),
    ]);
    std::fs::write("BENCH_failures.json", json.to_string()).expect("write BENCH_failures.json");
    println!("# wrote BENCH_failures.json");
}
