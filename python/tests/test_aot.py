"""AOT path: every entry lowers to clean HLO text the Rust client can load.

The hard constraint: no custom-calls (LAPACK, Mosaic) in any artifact --
xla_extension 0.5.1's CPU PJRT client has no registry for them.
"""

import json
import os

import pytest

from compile.aot import lower_entry
from compile.model import AOT_ENTRIES, D, K1, K3, N_FIT, N_SAMPLE

ENTRY_NAMES = sorted(AOT_ENTRIES)


@pytest.fixture(scope="module")
def lowered():
    return {name: lower_entry(name)[0] for name in ENTRY_NAMES}


@pytest.mark.parametrize("name", ENTRY_NAMES)
def test_no_custom_calls(lowered, name):
    assert "custom-call" not in lowered[name], f"{name} has a custom-call"


@pytest.mark.parametrize("name", ENTRY_NAMES)
def test_has_entry_computation(lowered, name):
    text = lowered[name]
    assert "ENTRY" in text
    assert "entry_computation_layout" in text


def test_em_step3_signature(lowered):
    head = lowered["gmm_em_step3"].splitlines()[0]
    assert f"f32[{N_FIT},{D}]" in head
    assert f"f32[{K3},{D},{D}]" in head


def test_sample3_signature(lowered):
    head = lowered["gmm_sample3"].splitlines()[0]
    assert f"f32[{N_SAMPLE},{D}]" in head
    assert f"f32[{K3}]" in head


def test_sample1_signature(lowered):
    head = lowered["gmm_sample1"].splitlines()[0]
    assert f"f32[{N_SAMPLE}]" in head
    assert f"f32[{K1}]" in head


def test_artifacts_dir_consistent_if_built():
    """If `make artifacts` has run, files + manifest must match AOT_ENTRIES."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(art, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("artifacts not built")
    with open(manifest_path) as f:
        manifest = json.load(f)
    assert set(manifest["modules"]) == set(AOT_ENTRIES)
    for name, info in manifest["modules"].items():
        path = os.path.join(art, info["file"])
        assert os.path.exists(path), f"missing artifact {path}"
        with open(path) as fh:
            assert "custom-call" not in fh.read()
    shapes = manifest["shapes"]
    assert shapes == {"N_FIT": N_FIT, "N_SAMPLE": N_SAMPLE, "D": D, "K3": K3, "K1": K1}
