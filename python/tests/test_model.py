"""L2 correctness: EM convergence/recovery, samplers, duration models."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels.ref import chol3_ref, tril3_inv_ref
from compile.model import (
    _pick_component,
    em_step1,
    em_step3,
    gmm_sample1,
    gmm_sample3,
    preproc_duration,
)


def _init3(rng, x, k):
    """k-random-row init mirroring what the Rust fitter does."""
    n = x.shape[0]
    logw = jnp.full((k,), -np.log(k), jnp.float32)
    mu = jnp.asarray(x[rng.choice(n, k, replace=False)], jnp.float32)
    pchol = jnp.broadcast_to(jnp.eye(3, dtype=jnp.float32), (k, 3, 3))
    return logw, mu, pchol


def _sample_true_gmm3(rng, n):
    """Three well-separated 3-D components with non-trivial covariance."""
    means = np.array([[-4.0, 0.0, 2.0], [3.0, 3.0, -2.0], [0.0, -4.0, 4.0]])
    a = rng.normal(size=(3, 3, 3)) * 0.3
    covs = a @ np.transpose(a, (0, 2, 1)) + 0.3 * np.eye(3)
    w = np.array([0.5, 0.3, 0.2])
    idx = rng.choice(3, size=n, p=w)
    chol = np.linalg.cholesky(covs)
    z = rng.normal(size=(n, 3))
    x = means[idx] + np.einsum("nde,ne->nd", chol[idx], z)
    return x.astype(np.float32), means, w


class TestEmStep3:
    def test_loglik_monotone(self):
        rng = np.random.default_rng(0)
        x, _, _ = _sample_true_gmm3(rng, 2048)
        x = jnp.asarray(x)
        logw, mu, pchol = _init3(rng, np.asarray(x), 8)
        lls = []
        for _ in range(25):
            logw, mu, _, pchol, ll = em_step3(x, logw, mu, pchol)
            lls.append(float(ll))
        # loglik reported is under *pre-step* params; after the first few
        # steps it must be non-decreasing (EM guarantee, fp tolerance).
        diffs = np.diff(lls[2:])
        assert np.all(diffs > -1e-2 * np.abs(np.array(lls[3:])).clip(min=1.0))
        assert lls[-1] > lls[0]

    def test_recovers_separated_means(self):
        rng = np.random.default_rng(1)
        x, true_means, true_w = _sample_true_gmm3(rng, 4096)
        x = jnp.asarray(x)
        logw, mu, pchol = _init3(rng, np.asarray(x), 3)
        for _ in range(60):
            logw, mu, cchol, pchol, ll = em_step3(x, logw, mu, pchol)
        mu = np.asarray(mu)
        w = np.exp(np.asarray(logw))
        # match each true mean to its closest recovered mean
        for tm, tw in zip(true_means, true_w):
            d = np.linalg.norm(mu - tm, axis=1)
            j = int(np.argmin(d))
            assert d[j] < 0.25, f"mean {tm} not recovered: {mu}"
            assert abs(w[j] - tw) < 0.05

    def test_weights_normalized_and_cchol_consistent(self):
        rng = np.random.default_rng(2)
        x, _, _ = _sample_true_gmm3(rng, 2048)
        x = jnp.asarray(x)
        logw, mu, pchol = _init3(rng, np.asarray(x), 6)
        logw, mu, cchol, pchol, _ = em_step3(x, logw, mu, pchol)
        np.testing.assert_allclose(np.exp(np.asarray(logw)).sum(), 1.0, rtol=1e-5)
        # pchol must be the inverse of cchol
        prod = np.asarray(pchol) @ np.asarray(cchol)
        np.testing.assert_allclose(
            prod, np.broadcast_to(np.eye(3), prod.shape), atol=2e-3
        )

    def test_closed_form_factorizations_roundtrip(self):
        rng = np.random.default_rng(3)
        a = rng.normal(size=(10, 3, 3)).astype(np.float32)
        spd = a @ np.transpose(a, (0, 2, 1)) + np.eye(3, dtype=np.float32)
        c = chol3_ref(jnp.asarray(spd))
        np.testing.assert_allclose(
            np.asarray(c) @ np.asarray(c).transpose(0, 2, 1), spd, rtol=1e-3, atol=1e-3
        )
        pc = tril3_inv_ref(c)
        np.testing.assert_allclose(
            np.asarray(pc) @ np.asarray(c),
            np.broadcast_to(np.eye(3), (10, 3, 3)),
            atol=1e-3,
        )


class TestEmStep1:
    def test_recovers_bimodal(self):
        rng = np.random.default_rng(4)
        n = 8192
        idx = rng.choice(2, size=n, p=[0.6, 0.4])
        x = np.where(idx == 0, rng.normal(2.0, 0.5, n), rng.normal(7.0, 1.0, n))
        x = jnp.asarray(x, jnp.float32)
        k = 2
        logw = jnp.full((k,), -np.log(k), jnp.float32)
        mu = jnp.asarray([0.0, 10.0], jnp.float32)
        logsd = jnp.zeros((k,), jnp.float32)
        for _ in range(50):
            logw, mu, logsd, ll = em_step1(x, logw, mu, logsd)
        mu = np.sort(np.asarray(mu))
        np.testing.assert_allclose(mu, [2.0, 7.0], atol=0.1)

    def test_loglik_monotone(self):
        rng = np.random.default_rng(5)
        x = jnp.asarray(np.concatenate([
            rng.normal(0, 1, 1024), rng.normal(5, 2, 1024)
        ]), jnp.float32)
        k = 4
        logw = jnp.full((k,), -np.log(k), jnp.float32)
        mu = jnp.asarray(rng.normal(2, 3, k), jnp.float32)
        logsd = jnp.zeros((k,), jnp.float32)
        lls = []
        for _ in range(30):
            logw, mu, logsd, ll = em_step1(x, logw, mu, logsd)
            lls.append(float(ll))
        assert lls[-1] > lls[0]
        diffs = np.diff(lls[2:])
        assert np.all(diffs > -1e-2 * np.abs(np.array(lls[3:])).clip(min=1.0))


class TestSamplers:
    def test_pick_component_frequencies(self):
        rng = np.random.default_rng(6)
        w = np.array([0.1, 0.2, 0.3, 0.4], np.float32)
        u = jnp.asarray(rng.uniform(size=200_000), jnp.float32)
        idx = np.asarray(_pick_component(jnp.log(jnp.asarray(w)), u))
        freq = np.bincount(idx, minlength=4) / len(idx)
        np.testing.assert_allclose(freq, w, atol=0.01)

    def test_sample3_moments(self):
        rng = np.random.default_rng(7)
        k = 3
        mu = rng.normal(size=(k, 3)).astype(np.float32) * 2
        a = rng.normal(size=(k, 3, 3)) * 0.4
        cov = (a @ np.transpose(a, (0, 2, 1)) + 0.2 * np.eye(3)).astype(np.float32)
        cchol = np.linalg.cholesky(cov).astype(np.float32)
        w = np.array([0.2, 0.5, 0.3], np.float32)
        n = 100_000
        u = jnp.asarray(rng.uniform(size=n), jnp.float32)
        z = jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)
        s = np.asarray(gmm_sample3(
            jnp.log(jnp.asarray(w)), jnp.asarray(mu), jnp.asarray(cchol), u, z
        ))
        want_mean = (w[:, None] * mu).sum(0)
        np.testing.assert_allclose(s.mean(0), want_mean, atol=0.05)
        # second moment: E[xx^T] = sum_k w_k (cov_k + mu_k mu_k^T)
        want_m2 = sum(w[k_] * (cov[k_] + np.outer(mu[k_], mu[k_])) for k_ in range(k))
        got_m2 = (s[:, :, None] * s[:, None, :]).mean(0)
        np.testing.assert_allclose(got_m2, want_m2, atol=0.15)

    def test_sample1_moments(self):
        rng = np.random.default_rng(8)
        w = np.array([0.3, 0.7], np.float32)
        mu = np.array([-2.0, 3.0], np.float32)
        sd = np.array([0.5, 1.5], np.float32)
        n = 200_000
        u = jnp.asarray(rng.uniform(size=n), jnp.float32)
        z = jnp.asarray(rng.normal(size=n), jnp.float32)
        s = np.asarray(gmm_sample1(
            jnp.log(jnp.asarray(w)), jnp.asarray(mu),
            jnp.asarray(np.log(sd)), u, z,
        ))
        want_mean = (w * mu).sum()
        want_var = (w * (sd**2 + mu**2)).sum() - want_mean**2
        np.testing.assert_allclose(s.mean(), want_mean, atol=0.03)
        np.testing.assert_allclose(s.var(), want_var, rtol=0.03)

    def test_sample3_deterministic_in_inputs(self):
        rng = np.random.default_rng(9)
        k = 2
        mu = jnp.zeros((k, 3), jnp.float32)
        cchol = jnp.broadcast_to(jnp.eye(3, dtype=jnp.float32), (k, 3, 3))
        logw = jnp.log(jnp.asarray([0.5, 0.5], jnp.float32))
        u = jnp.asarray(rng.uniform(size=64), jnp.float32)
        z = jnp.asarray(rng.normal(size=(64, 3)), jnp.float32)
        s1 = np.asarray(gmm_sample3(logw, mu, cchol, u, z))
        s2 = np.asarray(gmm_sample3(logw, mu, cchol, u, z))
        np.testing.assert_array_equal(s1, s2)


class TestPreprocDuration:
    def test_matches_paper_formula(self):
        """t = a*b**x + c + LogNormal(mu_n, sigma_n), paper Fig 9a params."""
        rng = np.random.default_rng(10)
        x = rng.uniform(2, 20, size=256).astype(np.float32)
        z = rng.normal(size=256).astype(np.float32)
        abc = np.array([0.018, 1.330, 2.156], np.float32)
        noise = np.array([-1.0, 0.15], np.float32)
        got = np.asarray(preproc_duration(
            jnp.asarray(x), jnp.asarray(abc), jnp.asarray(noise), jnp.asarray(z)
        ))
        want = 0.018 * 1.330**x + 2.156 + np.exp(-1.0 + 0.15 * z)
        np.testing.assert_allclose(got, want, rtol=1e-4)

    def test_durations_positive_and_monotone_in_size(self):
        x = jnp.asarray(np.linspace(2, 25, 128), jnp.float32)
        z = jnp.zeros(128, jnp.float32)
        abc = jnp.asarray([0.018, 1.330, 2.156], jnp.float32)
        noise = jnp.asarray([-1.0, 0.15], jnp.float32)
        t = np.asarray(preproc_duration(x, abc, noise, z))
        assert np.all(t > 0)
        assert np.all(np.diff(t) > 0)
