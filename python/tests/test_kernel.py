"""L1 correctness: Pallas kernels vs the pure-jnp oracle (kernels/ref.py).

This is the core correctness signal for the AOT path: everything the Rust
runtime executes flows through these kernels.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gmm import gmm_logpdf, gmm_logpdf1
from compile.kernels.ref import (
    chol3_ref,
    gmm_logpdf1_ref,
    gmm_logpdf_ref,
    tril3_inv_ref,
)


def _rand_gmm_params(rng, k, d):
    logw = jnp.asarray(np.log(rng.dirichlet(np.ones(k))), jnp.float32)
    mu = jnp.asarray(rng.normal(size=(k, d)) * 3.0, jnp.float32)
    # random SPD covariance -> cchol -> pchol
    a = rng.normal(size=(k, d, d))
    cov = a @ np.transpose(a, (0, 2, 1)) + 0.5 * np.eye(d)
    cchol = np.linalg.cholesky(cov)
    pchol = np.linalg.inv(cchol)
    # np.linalg.inv of lower-tri is lower-tri up to fp noise; mask exactly
    pchol = np.tril(pchol)
    return logw, mu, jnp.asarray(pchol, jnp.float32), jnp.asarray(cchol, jnp.float32)


class TestGmmLogpdf3D:
    def test_matches_ref_default_shapes(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(2048, 3)) * 2.0, jnp.float32)
        logw, mu, pchol, _ = _rand_gmm_params(rng, 50, 3)
        got = gmm_logpdf(x, logw, mu, pchol)
        want = gmm_logpdf_ref(x, logw, mu, pchol)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_matches_scipy_density(self):
        """Cross-check the *oracle* against scipy's multivariate normal."""
        from scipy.stats import multivariate_normal

        rng = np.random.default_rng(1)
        x = rng.normal(size=(64, 3)).astype(np.float32)
        logw, mu, pchol, cchol = _rand_gmm_params(rng, 4, 3)
        want = np.stack(
            [
                np.asarray(logw)[k]
                + multivariate_normal(
                    np.asarray(mu)[k],
                    np.asarray(cchol)[k] @ np.asarray(cchol)[k].T,
                ).logpdf(x)
                for k in range(4)
            ],
            axis=1,
        )
        got = gmm_logpdf(jnp.asarray(x), logw, mu, pchol, block_n=64)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_rejects_nondivisible_n(self):
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(100, 3)), jnp.float32)
        logw, mu, pchol, _ = _rand_gmm_params(rng, 3, 3)
        with pytest.raises(ValueError, match="not divisible"):
            gmm_logpdf(x, logw, mu, pchol)

    @settings(max_examples=20, deadline=None)
    @given(
        n_blocks=st.integers(1, 4),
        block=st.sampled_from([8, 64, 128]),
        k=st.integers(1, 50),
        d=st.integers(2, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, n_blocks, block, k, d, seed):
        rng = np.random.default_rng(seed)
        n = n_blocks * block
        x = jnp.asarray(rng.normal(size=(n, d)) * 2.0, jnp.float32)
        logw = jnp.asarray(np.log(rng.dirichlet(np.ones(k))), jnp.float32)
        mu = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)
        a = rng.normal(size=(k, d, d))
        cov = a @ np.transpose(a, (0, 2, 1)) + 0.5 * np.eye(d)
        pchol = jnp.asarray(np.tril(np.linalg.inv(np.linalg.cholesky(cov))), jnp.float32)
        got = gmm_logpdf(x, logw, mu, pchol, block_n=block)
        want = gmm_logpdf_ref(x, logw, mu, pchol)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestGmmLogpdf1D:
    def test_matches_ref(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(2048,)) * 4.0, jnp.float32)
        logw = jnp.asarray(np.log(rng.dirichlet(np.ones(8))), jnp.float32)
        mu = jnp.asarray(rng.normal(size=(8,)) * 3.0, jnp.float32)
        logsd = jnp.asarray(rng.normal(size=(8,)) * 0.3, jnp.float32)
        got = gmm_logpdf1(x, logw, mu, logsd)
        want = gmm_logpdf1_ref(x, logw, mu, logsd)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_matches_scipy_norm(self):
        from scipy.stats import norm

        rng = np.random.default_rng(4)
        x = rng.normal(size=(128,)).astype(np.float32)
        mu = np.array([-1.0, 0.5], np.float32)
        sd = np.array([0.7, 2.0], np.float32)
        logw = np.log(np.array([0.3, 0.7], np.float32))
        want = logw[None, :] + np.stack(
            [norm(mu[k], sd[k]).logpdf(x) for k in range(2)], axis=1
        )
        got = gmm_logpdf1(
            jnp.asarray(x), jnp.asarray(logw), jnp.asarray(mu),
            jnp.asarray(np.log(sd)), block_n=128,
        )
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    @settings(max_examples=15, deadline=None)
    @given(
        n_blocks=st.integers(1, 3),
        k=st.integers(1, 16),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, n_blocks, k, seed):
        rng = np.random.default_rng(seed)
        n = n_blocks * 128
        x = jnp.asarray(rng.normal(size=(n,)) * 3.0, jnp.float32)
        logw = jnp.asarray(np.log(rng.dirichlet(np.ones(k))), jnp.float32)
        mu = jnp.asarray(rng.normal(size=(k,)), jnp.float32)
        logsd = jnp.asarray(rng.normal(size=(k,)) * 0.3, jnp.float32)
        got = gmm_logpdf1(x, logw, mu, logsd, block_n=128)
        want = gmm_logpdf1_ref(x, logw, mu, logsd)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestChol3:
    """The hand-unrolled 3x3 factorizations vs LAPACK (test-time only)."""

    @settings(max_examples=25, deadline=None)
    @given(k=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
    def test_chol3_matches_lapack(self, k, seed):
        rng = np.random.default_rng(seed)
        a = rng.normal(size=(k, 3, 3))
        spd = (a @ np.transpose(a, (0, 2, 1)) + 0.5 * np.eye(3)).astype(np.float32)
        got = chol3_ref(jnp.asarray(spd))
        want = np.linalg.cholesky(spd.astype(np.float64))
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)

    @settings(max_examples=25, deadline=None)
    @given(k=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
    def test_tril3_inv_is_inverse(self, k, seed):
        rng = np.random.default_rng(seed)
        a = rng.normal(size=(k, 3, 3))
        spd = a @ np.transpose(a, (0, 2, 1)) + 0.5 * np.eye(3)
        l = np.linalg.cholesky(spd).astype(np.float32)
        inv = np.asarray(tril3_inv_ref(jnp.asarray(l)))
        prod = inv @ l
        np.testing.assert_allclose(prod, np.broadcast_to(np.eye(3), (k, 3, 3)),
                                   rtol=1e-3, atol=1e-3)

    def test_tril3_inv_is_lower_triangular(self):
        rng = np.random.default_rng(7)
        a = rng.normal(size=(16, 3, 3))
        spd = a @ np.transpose(a, (0, 2, 1)) + 0.5 * np.eye(3)
        l = np.linalg.cholesky(spd).astype(np.float32)
        inv = np.asarray(tril3_inv_ref(jnp.asarray(l)))
        assert np.allclose(np.triu(inv, 1), 0.0)
