"""L1 Pallas kernel: batched GMM log-density matrix (the EM E-step hot-spot).

The kernel computes ``logp[n, k] = log w_k + log N(x_n | mu_k, Sigma_k)``
for a tile of rows at a time. This is the dominant FLOP cost of fitting
the paper's 50-component full-covariance asset mixture (Fig 8) and the
per-framework duration mixtures (Fig 9b): an (N x K x D x D) batch of tiny
Mahalanobis transforms reshaped into MXU-friendly dots.

TPU mapping (see DESIGN.md section Hardware-Adaptation):
  * grid axis = row tiles of BLOCK_N (HBM -> VMEM staging via BlockSpec);
  * the K axis (component parameters: mu, pchol, logw) stays VMEM-resident
    across the whole grid (~2.6 KB for K=50, D=3);
  * the (x - mu) @ pchol^T contraction is expressed with jnp.einsum so it
    lowers to dot_general (MXU) rather than scalar loops.

interpret=True is mandatory here: the artifacts must run on the Rust CPU
PJRT client, which cannot execute Mosaic custom-calls. Correctness is
asserted against kernels/ref.py by the pytest suite.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import LOG_2PI

# Row-tile size. VMEM budget per tile at K=50, D=3 (f32):
#   in 2048x3 (24 KB) + out 2048x50 (400 KB) + params (~2.6 KB) ~ 430 KB,
# comfortably inside a 16 MB VMEM with room for double buffering; larger
# tiles amortize grid-loop overhead on both TPU and the interpret path.
BLOCK_N = 2048


def _gmm_logpdf_kernel(x_ref, logw_ref, mu_ref, pchol_ref, o_ref):
    """One row-tile of the log-density matrix.

    x_ref:     (BLOCK_N, D) tile of data rows.
    logw_ref:  (K,) log weights (full, VMEM-resident).
    mu_ref:    (K, D) means (full).
    pchol_ref: (K, D, D) lower-triangular inverse-covariance-Cholesky (full).
    o_ref:     (BLOCK_N, K) output tile.
    """
    x = x_ref[...]
    logw = logw_ref[...]
    mu = mu_ref[...]
    pchol = pchol_ref[...]
    d = x.shape[1]

    diff = x[:, None, :] - mu[None, :, :]             # (BN, K, D)
    # y[n,k,:] = pchol_k @ diff[n,k,:]  -- batched small matmul (dot_general)
    y = jnp.einsum("kde,nke->nkd", pchol, diff)
    maha = jnp.sum(y * y, axis=-1)                    # (BN, K)
    logdet = jnp.sum(
        jnp.log(jnp.abs(jnp.diagonal(pchol, axis1=1, axis2=2))), axis=1
    )
    o_ref[...] = logw[None, :] + logdet[None, :] - 0.5 * d * LOG_2PI - 0.5 * maha


@functools.partial(jax.jit, static_argnames=("block_n",))
def gmm_logpdf(x, logw, mu, pchol, *, block_n=BLOCK_N):
    """Pallas-tiled GMM log joint density.

    Args mirror ref.gmm_logpdf_ref. N must be divisible by block_n.
    Returns (N, K) f32.
    """
    n, d = x.shape
    k = logw.shape[0]
    if n % block_n != 0:
        raise ValueError(f"N={n} not divisible by block_n={block_n}")
    grid = (n // block_n,)
    return pl.pallas_call(
        _gmm_logpdf_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k, d, d), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=True,
    )(x, logw, mu, pchol)


def _gmm_logpdf1_kernel(x_ref, logw_ref, mu_ref, logsd_ref, o_ref):
    """1-D mixture tile: logp[n,k] = logw_k + log N(x_n | mu_k, sd_k^2)."""
    x = x_ref[...]                                    # (BN,)
    logw = logw_ref[...]
    mu = mu_ref[...]
    logsd = logsd_ref[...]
    z = (x[:, None] - mu[None, :]) * jnp.exp(-logsd)[None, :]
    o_ref[...] = logw[None, :] - logsd[None, :] - 0.5 * LOG_2PI - 0.5 * z * z


@functools.partial(jax.jit, static_argnames=("block_n",))
def gmm_logpdf1(x, logw, mu, logsd, *, block_n=BLOCK_N):
    """Pallas-tiled 1-D GMM log joint density. Returns (N, K) f32."""
    n = x.shape[0]
    k = logw.shape[0]
    if n % block_n != 0:
        raise ValueError(f"N={n} not divisible by block_n={block_n}")
    grid = (n // block_n,)
    return pl.pallas_call(
        _gmm_logpdf1_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=True,
    )(x, logw, mu, logsd)
