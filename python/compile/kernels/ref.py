"""Pure-jnp oracles for the Pallas kernels and L2 building blocks.

Everything here is the *reference* implementation: numerically
straightforward, no tiling, no Pallas. The pytest suite asserts that the
Pallas kernel (kernels/gmm.py) and the AOT-lowered model functions
(compile/model.py) agree with these to tight tolerances.
"""

import jax.numpy as jnp

LOG_2PI = 1.8378770664093453


def gmm_logpdf_ref(x, logw, mu, pchol):
    """Log joint density log w_k + log N(x_n | mu_k, Sigma_k).

    Args:
      x:     (N, D) data.
      logw:  (K,) log mixture weights.
      mu:    (K, D) component means.
      pchol: (K, D, D) lower-triangular C^{-1}, the inverse of the
             covariance Cholesky factor, so that the precision is
             P = pchol^T pchol and the Mahalanobis distance is
             ||pchol (x - mu)||^2.

    Returns:
      (N, K) log densities.
    """
    d = x.shape[1]
    diff = x[:, None, :] - mu[None, :, :]              # (N, K, D)
    y = jnp.einsum("kde,nke->nkd", pchol, diff)        # (N, K, D)
    maha = jnp.sum(y * y, axis=-1)                     # (N, K)
    logdet = jnp.sum(
        jnp.log(jnp.abs(jnp.diagonal(pchol, axis1=1, axis2=2))), axis=1
    )                                                  # (K,)
    return logw[None, :] + logdet[None, :] - 0.5 * d * LOG_2PI - 0.5 * maha


def gmm_logpdf1_ref(x, logw, mu, logsd):
    """1-D version: log w_k + log N(x_n | mu_k, sd_k^2).

    Args: x (N,), logw/mu/logsd (K,). Returns (N, K).
    """
    z = (x[:, None] - mu[None, :]) * jnp.exp(-logsd)[None, :]
    return logw[None, :] - logsd[None, :] - 0.5 * LOG_2PI - 0.5 * z * z


def chol3_ref(a):
    """Closed-form Cholesky of a batch of 3x3 SPD matrices, (K,3,3)->(K,3,3).

    Hand-unrolled: jnp.linalg.cholesky lowers to a LAPACK custom-call on
    CPU which the Rust PJRT client (xla_extension 0.5.1) cannot execute,
    so the AOT path must stay custom-call-free.
    """
    l11 = jnp.sqrt(a[:, 0, 0])
    l21 = a[:, 1, 0] / l11
    l31 = a[:, 2, 0] / l11
    l22 = jnp.sqrt(a[:, 1, 1] - l21 * l21)
    l32 = (a[:, 2, 1] - l31 * l21) / l22
    l33 = jnp.sqrt(a[:, 2, 2] - l31 * l31 - l32 * l32)
    z = jnp.zeros_like(l11)
    return jnp.stack(
        [
            jnp.stack([l11, z, z], axis=-1),
            jnp.stack([l21, l22, z], axis=-1),
            jnp.stack([l31, l32, l33], axis=-1),
        ],
        axis=1,
    )


def tril3_inv_ref(l):
    """Closed-form inverse of a batch of lower-triangular 3x3 matrices."""
    i11 = 1.0 / l[:, 0, 0]
    i22 = 1.0 / l[:, 1, 1]
    i33 = 1.0 / l[:, 2, 2]
    i21 = -l[:, 1, 0] * i11 * i22
    i31 = (l[:, 1, 0] * l[:, 2, 1] - l[:, 1, 1] * l[:, 2, 0]) * i11 * i22 * i33
    i32 = -l[:, 2, 1] * i22 * i33
    z = jnp.zeros_like(i11)
    return jnp.stack(
        [
            jnp.stack([i11, z, z], axis=-1),
            jnp.stack([i21, i22, z], axis=-1),
            jnp.stack([i31, i32, i33], axis=-1),
        ],
        axis=1,
    )
