"""AOT-lower the L2 model functions to HLO *text* artifacts.

HLO text (NOT ``lowered.compile().serialize()`` / serialized HloModuleProto)
is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects (`proto.id() <=
INT_MAX`). The text parser reassigns ids, so text round-trips cleanly.
See /opt/xla-example/load_hlo/ and gen_hlo.py there.

Usage (from python/):  python -m compile.aot --out ../artifacts

Writes one <name>.hlo.txt per AOT entry plus manifest.json recording the
shapes the Rust runtime must feed.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import AOT_ENTRIES, D, K1, K3, N_FIT, N_SAMPLE


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name: str):
    fn, specs = AOT_ENTRIES[name]
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered), specs


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output dir")
    parser.add_argument(
        "--only", default=None, help="comma-separated subset of entries"
    )
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    names = list(AOT_ENTRIES) if not args.only else args.only.split(",")
    manifest = {
        "shapes": {
            "N_FIT": N_FIT,
            "N_SAMPLE": N_SAMPLE,
            "D": D,
            "K3": K3,
            "K1": K1,
        },
        "modules": {},
    }
    for name in names:
        text, specs = lower_entry(name)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["modules"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [list(s.shape) for s in specs],
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
