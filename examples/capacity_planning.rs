//! Capacity planning (the Fig 11 scenario + the paper's stated use case).
//!
//! The paper's dashboard walkthrough: an arrival peak around 16:00
//! saturates the learning cluster, jobs queue, and post-processing tasks
//! are delayed. Here we sweep the training-cluster capacity, watch
//! utilization / queue wait / pipeline wait respond, and also ablate
//! every registered scheduling strategy (FIFO, shortest-job-first,
//! priority, earliest-deadline-first, weighted-fair, ...) — the
//! operational strategies the framework exists to evaluate (Fig 4).
//!
//! Run: `cargo run --release --example capacity_planning`

use std::sync::Arc;

use pipesim::coordinator::{
    fit_params, scheduler_names, ArrivalSpec, Experiment, ExperimentConfig, StrategySpec,
};
use pipesim::des::DAY;
use pipesim::empirical::GroundTruth;
use pipesim::runtime::Runtime;

fn main() -> pipesim::Result<()> {
    let db = GroundTruth::new(7).generate_weeks(6);
    let runtime = Runtime::load_default().map(Arc::new);
    let params = fit_params(&db, runtime.clone())?;

    println!("== capacity sweep: 7 days each, realistic arrival profile ==");
    println!(
        "{:>9} {:>11} {:>12} {:>14} {:>14} {:>11}",
        "capacity", "util_train", "queue_len", "mean_wait_s", "p_completed", "max_wait_s"
    );
    for capacity in [2, 4, 6, 8, 12, 16, 24] {
        let mut cfg = ExperimentConfig {
            name: format!("cap-{capacity}"),
            seed: 11,
            horizon: 7.0 * DAY,
            arrival: ArrivalSpec::Profile,
            record_traces: false,
            ..Default::default()
        };
        cfg.infra.training_capacity = capacity;
        let r = Experiment::new(cfg, params.clone())
            .with_runtime(runtime.clone())
            .run()?;
        println!(
            "{:>9} {:>10.1}% {:>12.2} {:>14.1} {:>13.1}% {:>11.0}",
            capacity,
            100.0 * r.util_training,
            r.avg_queue_training,
            r.wait_training.mean(),
            100.0 * r.completed as f64 / r.arrived as f64,
            r.wait_training.max,
        );
    }

    println!();
    println!("== scheduler ablation at tight capacity (4 slots) ==");
    println!(
        "{:>14} {:>14} {:>14} {:>12}",
        "scheduler", "mean_wait_s", "max_wait_s", "completed"
    );
    for name in scheduler_names() {
        let mut cfg = ExperimentConfig {
            name: format!("sched-{name}"),
            seed: 11,
            horizon: 7.0 * DAY,
            arrival: ArrivalSpec::Profile,
            record_traces: false,
            ..Default::default()
        };
        cfg.infra.training_capacity = 4;
        cfg.infra.scheduler = StrategySpec::new(&name);
        let r = Experiment::new(cfg, params.clone())
            .with_runtime(runtime.clone())
            .run()?;
        println!(
            "{:>14} {:>14.1} {:>14.0} {:>12}",
            name,
            r.wait_training.mean(),
            r.wait_training.max,
            r.completed
        );
    }
    println!();
    println!("(shortest-job-first should cut the mean wait vs FIFO at the");
    println!(" cost of long-job starvation, visible in the max wait)");
    Ok(())
}
