//! Framework-trend what-if (paper section V-A2b): "the number of
//! TensorFlow builds is increasing over time" — the paper wants to
//! "easily adapt these percentages to observe the effect on the system".
//!
//! Sweep the TensorFlow share from the production 32% up to 80% and watch
//! the training cluster saturate: TF jobs run ~18x longer than SparkML
//! (median 180 s vs 10 s), so a TF-heavy mix starves the cluster at the
//! same arrival rate.
//!
//! Run: `cargo run --release --example framework_trend`

use std::sync::Arc;

use pipesim::coordinator::{fit_params, ArrivalSpec, Experiment, ExperimentConfig};
use pipesim::des::DAY;
use pipesim::empirical::GroundTruth;
use pipesim::runtime::Runtime;
use pipesim::synth::SynthConfig;

fn main() -> pipesim::Result<()> {
    let db = GroundTruth::new(13).generate_weeks(6);
    let runtime = Runtime::load_default().map(Arc::new);
    let params = fit_params(&db, runtime.clone())?;

    println!("== TensorFlow share sweep (7 days, fixed infra) ==");
    println!(
        "{:>9} {:>11} {:>13} {:>14} {:>12}",
        "tf_share", "util_train", "queue_train", "mean_wait_s", "completed%"
    );
    for tf_share in [0.32, 0.45, 0.60, 0.70, 0.80] {
        let cfg = ExperimentConfig {
            name: format!("tf-{tf_share}"),
            seed: 3,
            horizon: 7.0 * DAY,
            arrival: ArrivalSpec::Profile,
            synth: SynthConfig::default().with_tensorflow_share(tf_share),
            record_traces: false,
            ..Default::default()
        };
        let r = Experiment::new(cfg, params.clone())
            .with_runtime(runtime.clone())
            .run()?;
        println!(
            "{:>8.0}% {:>10.1}% {:>13.2} {:>14.1} {:>11.1}%",
            100.0 * tf_share,
            100.0 * r.util_training,
            r.avg_queue_training,
            r.wait_training.mean(),
            100.0 * r.completed as f64 / r.arrived as f64,
        );
    }
    println!();
    println!("(utilization and queueing must rise monotonically with the TF share)");
    Ok(())
}
