//! Write-your-own-placer walkthrough (the README's ~20-line example).
//!
//! A "value" placer: rank classes by speed per dollar, so jobs land
//! where a slot-second buys the most work — fast-but-fairly-priced
//! classes beat both a slow bargain bin and an overpriced flagship. It
//! is registered under a name, so it becomes selectable from JSON
//! config and sweepable from the CLI exactly like the built-ins — no
//! simulator-core changes involved.
//!
//! Run: `cargo run --release --example custom_placer`

use std::sync::Arc;

use pipesim::coordinator::{
    build_placer, fit_params, register_placer, ArrivalSpec, ExperimentConfig, StrategySpec, Sweep,
};
use pipesim::des::{ClassView, PlaceCtx, Placer};
use pipesim::empirical::GroundTruth;
use pipesim::model::{HwClass, HwClasses};
use pipesim::Result;

// --- the strategy: ~20 lines from here ----------------------------------

/// Prefer the class with the best speed-per-dollar; free classes win
/// outright (their value is infinite), price ties go to the faster one.
struct BestValue {
    /// Price floor: below this, a class counts as free.
    free_below: f64,
}

impl Placer for BestValue {
    fn name(&self) -> &'static str {
        "best_value"
    }

    /// Lower score wins; negated value turns "most work per dollar"
    /// into the minimum. The default `place` handles fitting/spill.
    fn score(&mut self, class: &ClassView, _ctx: &PlaceCtx) -> f64 {
        if class.cost_per_sec <= self.free_below {
            return f64::NEG_INFINITY;
        }
        -(class.speed / class.cost_per_sec)
    }
}

/// Constructor: numeric params arrive via the spec.
fn best_value_ctor(spec: &StrategySpec) -> Result<Box<dyn Placer>> {
    spec.check_keys(&["free_below"])?;
    Ok(Box::new(BestValue {
        free_below: spec.get_or("free_below", 0.0),
    }))
}

// --- that's it. Register + use it like any built-in ---------------------

fn main() -> Result<()> {
    register_placer("best_value", best_value_ctor);
    // selectable via the registry from a spec (equivalently from JSON:
    // {"hw_classes": {"placer": {"name": "best_value", "params": ...}}})
    let spec = StrategySpec::parse("best_value:free_below=0.0005")?;
    assert_eq!(build_placer(&spec)?.name(), "best_value");

    let db = GroundTruth::new(7).generate_weeks(4);
    let params = Arc::new(fit_params(&db, None)?);

    // a mixed fleet: an overpriced flagship, a balanced midrange class,
    // and a slow bargain class — best_value should favor the midrange
    let fleet = |placer: StrategySpec| HwClasses {
        training: vec![
            HwClass::new("flagship", 1).with_speed(2.0).with_cost(0.008),
            HwClass::new("midrange", 2).with_speed(1.5).with_cost(0.002),
            HwClass::new("bargain", 3).with_speed(0.8).with_cost(0.0008),
        ],
        compute: Vec::new(),
        placer,
    };

    // sweep it against the built-in extremes under moderate load
    let mut sweep = Sweep::new(params).jobs(0);
    for placer in ["fastest_fit", "cheapest_fit", "best_value:free_below=0.0005"] {
        let mut cfg = ExperimentConfig {
            name: placer.split(':').next().unwrap_or(placer).into(),
            horizon: 3.0 * 86_400.0,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 240.0,
            },
            record_traces: false,
            ..Default::default()
        };
        cfg.infra.training_capacity = 6;
        cfg.infra.hw_classes = Some(fleet(StrategySpec::parse(placer)?));
        sweep.add_replications(&cfg, 1, 4);
    }
    let out = sweep.run()?;
    print!("{}", out.table());
    println!("(best_value trades a little speed for a much smaller bill)");
    Ok(())
}
