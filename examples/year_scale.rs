//! End-to-end headline run (Fig 13): simulate a full year of pipeline
//! executions at the paper's load and measure simulator performance.
//!
//! The paper: 365 days at an average 44 s interarrival ≈ 720,000 pipeline
//! executions, simulated in ~8.6 min (≈1.4 ms/pipeline) on an FX-8350,
//! with ~850 MB peak memory and linear time scaling. This driver
//! exercises ALL layers on the same workload: empirical generation → PJRT
//! EM fitting → synthesizers + batched PJRT sampling → DES engine →
//! analytics, and prints the scaling table + the year-long headline row.
//!
//! Run: `cargo run --release --example year_scale`

use std::sync::Arc;

use pipesim::coordinator::{fit_params, ArrivalSpec, Experiment, ExperimentConfig};
use pipesim::des::DAY;
use pipesim::empirical::GroundTruth;
use pipesim::runtime::Runtime;

fn main() -> pipesim::Result<()> {
    let db = GroundTruth::new(5).generate_weeks(8);
    let runtime = Runtime::load_default().map(Arc::new);
    println!(
        "sampler backend: {}",
        if runtime.is_some() { "pjrt (AOT artifacts)" } else { "cpu fallback" }
    );
    let params = fit_params(&db, runtime.clone())?;

    // --- Fig 13 sweep: pipelines vs wall-clock and memory -------------
    println!("\n== scaling sweep (flat 44 s interarrival, traces off) ==");
    println!(
        "{:>10} {:>11} {:>15} {:>14} {:>12}",
        "pipelines", "wall_s", "us/pipeline", "events/s", "peak_rss_mb"
    );
    let mut rows = Vec::new();
    for n in [1_000u64, 5_000, 10_000, 50_000, 100_000, 300_000] {
        let cfg = ExperimentConfig {
            name: format!("scale-{n}"),
            seed: 1,
            horizon: f64::MAX / 4.0,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 44.0,
            },
            max_pipelines: Some(n),
            record_traces: false,
            sample_interval: 3600.0,
            ..Default::default()
        };
        let r = Experiment::new(cfg, params.clone())
            .with_runtime(runtime.clone())
            .run()?;
        println!(
            "{:>10} {:>11.3} {:>15.2} {:>14.0} {:>12.1}",
            n,
            r.wall_secs,
            r.us_per_pipeline(),
            r.events_per_sec(),
            r.peak_rss_mb
        );
        rows.push((n as f64, r.wall_secs));
    }
    // linearity check: wall time per pipeline at largest vs smallest scale
    let small = rows[0].1 / rows[0].0;
    let large = rows[rows.len() - 1].1 / rows[rows.len() - 1].0;
    println!(
        "time/pipeline smallest vs largest scale: {:.2} µs vs {:.2} µs (ratio {:.2}, ~1.0 = linear)",
        small * 1e6,
        large * 1e6,
        large / small
    );

    // --- headline: 365 days @ 44 s ≈ 720k pipelines --------------------
    println!("\n== headline: 365 simulated days @ 44 s mean interarrival ==");
    let cfg = ExperimentConfig {
        name: "year".into(),
        seed: 1,
        horizon: 365.0 * DAY,
        arrival: ArrivalSpec::Poisson {
            mean_interarrival: 44.0,
        },
        record_traces: false,
        sample_interval: 3600.0,
        ..Default::default()
    };
    let r = Experiment::new(cfg, params).with_runtime(runtime).run()?;
    println!("{}", r.summary());
    println!(
        "paper: ~720k pipelines in ~517 s (1.4 ms each). this run: {} pipelines in {:.1} s ({:.1} µs each, {:.0}x faster)",
        r.arrived,
        r.wall_secs,
        r.us_per_pipeline(),
        1400.0 / r.us_per_pipeline().max(1e-9) * 1.0
    );
    Ok(())
}
