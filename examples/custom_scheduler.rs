//! Write-your-own-scheduler walkthrough (the README's ~30-line example).
//!
//! A "headroom" scheduler: keep `reserve` slots free for urgent work
//! (priority class <= 1, which includes retraining pipelines at class 0),
//! and order the queue by priority. It is registered under a name, so it
//! becomes selectable from JSON config and sweepable from the CLI exactly
//! like the built-ins — no simulator-core changes involved.
//!
//! Run: `cargo run --release --example custom_scheduler`

use std::sync::Arc;

use pipesim::coordinator::{
    build_scheduler, fit_params, register_scheduler, ArrivalSpec, ExperimentConfig, StrategySpec,
    Sweep,
};
use pipesim::des::{SchedCtx, Scheduler};
use pipesim::empirical::GroundTruth;
use pipesim::Result;

// --- the strategy: ~30 lines from here ---------------------------------

/// Reserve the last `reserve` slots for priority classes <= 1.
struct Headroom {
    reserve: usize,
}

impl Scheduler for Headroom {
    fn name(&self) -> &'static str {
        "headroom"
    }

    /// Bulk work may not take a slot into the reserved band; urgent work
    /// (class <= 1) always may. No idle-deadlock worry: the resource
    /// itself always admits at `in_use == 0` and skips this call.
    fn admit(&mut self, ctx: &SchedCtx) -> bool {
        ctx.job.priority <= 1.0 || ctx.in_use + self.reserve < ctx.capacity
    }

    /// Queue order: priority class, ties FIFO (the resource adds the
    /// enqueue-sequence tie-break).
    fn queue_key(&mut self, ctx: &SchedCtx) -> f64 {
        ctx.job.priority
    }
}

/// Constructor: numeric params arrive via the spec.
fn headroom_ctor(spec: &StrategySpec) -> Result<Box<dyn Scheduler>> {
    spec.check_keys(&["reserve"])?;
    Ok(Box::new(Headroom {
        reserve: spec.get_or("reserve", 1.0).max(0.0) as usize,
    }))
}

// --- that's it. Register + use it like any built-in ---------------------

fn main() -> Result<()> {
    register_scheduler("headroom", headroom_ctor);
    // selectable via the registry from a spec (equivalently from JSON:
    // {"scheduler": {"name": "headroom", "params": {"reserve": 2}}})
    let spec = StrategySpec::parse("headroom:reserve=2")?;
    assert_eq!(build_scheduler(&spec)?.name(), "headroom");

    let db = GroundTruth::new(7).generate_weeks(4);
    let params = Arc::new(fit_params(&db, None)?);

    // sweep it against the FIFO baseline under saturation
    let mut sweep = Sweep::new(params).jobs(0);
    for sched in ["fifo", "headroom:reserve=2"] {
        let mut cfg = ExperimentConfig {
            name: sched.replace(':', "_"),
            horizon: 3.0 * 86_400.0,
            arrival: ArrivalSpec::Poisson {
                mean_interarrival: 30.0,
            },
            record_traces: false,
            ..Default::default()
        };
        cfg.infra.training_capacity = 4;
        cfg.infra.scheduler = StrategySpec::parse(sched)?;
        sweep.add_replications(&cfg, 1, 4);
    }
    let out = sweep.run()?;
    print!("{}", out.table());
    println!("(headroom trades bulk throughput for urgent-work latency)");
    Ok(())
}
