//! Trace round-trip: capture a run's event trace, export it to the
//! binary format, re-ingest it, and replay it — proving the replay
//! reproduces the original run's outcome digest byte-for-byte (the trace
//! subsystem's core guarantee).
//!
//! 1. Fit simulation parameters on a small synthetic empirical DB.
//! 2. Run 2 days with `capture_trace` on and export `trace.pst`.
//! 3. Load the file, summarize it, Q-Q it against the fits.
//! 4. Replay through `TraceWorkload` and compare digests.
//!
//! Run: `cargo run --release --example trace_roundtrip`

use pipesim::analytics::{trace_qq, TraceSummary};
use pipesim::coordinator::{fit_params, ArrivalSpec, Experiment, ExperimentConfig};
use pipesim::des::DAY;
use pipesim::empirical::GroundTruth;
use pipesim::trace::{Trace, TraceWorkload};

fn main() -> pipesim::Result<()> {
    println!("== fitting parameters (3-week synthetic empirical DB) ==");
    let db = GroundTruth::new(11).generate_weeks(3);
    let params = fit_params(&db, None)?;

    println!("== capturing a 2-day run ==");
    let cfg = ExperimentConfig {
        name: "trace-roundtrip".into(),
        seed: 7,
        horizon: 2.0 * DAY,
        arrival: ArrivalSpec::Profile,
        capture_trace: true,
        ..Default::default()
    };
    let mut captured = Experiment::new(cfg, params.clone()).run()?;
    let trace = captured.trace.take().expect("capture_trace was on");
    let digest_captured = captured.digest();
    println!(
        "captured {} events from {} pipelines",
        trace.len(),
        captured.arrived
    );

    let path = std::env::temp_dir().join("pipesim_trace_roundtrip.pst");
    trace.save(&path)?;
    let on_disk = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    println!(
        "exported {} ({} bytes, {:.1} B/event)",
        path.display(),
        on_disk,
        on_disk as f64 / trace.len().max(1) as f64
    );

    println!("== re-ingesting + analyzing ==");
    let loaded = Trace::load(&path)?;
    assert_eq!(loaded, trace, "binary round-trip must be lossless");
    print!("{}", TraceSummary::from_trace(&loaded).render());
    for q in trace_qq(&loaded, &params, 20_000, 40, 1) {
        println!("{}", q.verdict());
    }

    println!("== replaying ==");
    let workload = TraceWorkload::from_trace(&loaded)?;
    let replayed = workload.run(params, None)?;
    let digest_replayed = replayed.digest();
    println!("captured digest: {digest_captured}");
    println!("replayed digest: {digest_replayed}");
    assert_eq!(
        digest_captured, digest_replayed,
        "capture -> replay must round-trip bit-identically"
    );
    println!("round-trip OK: digests are byte-identical");
    std::fs::remove_file(&path).ok();
    Ok(())
}
