//! Simulation accuracy (Fig 12): fit on the empirical DB, simulate four
//! weeks, and compare simulated vs empirical distributions — Q-Q of task
//! durations per stratum (12a), interarrivals for both arrival modes
//! (12b), and the hour-of-week arrival overlay (12c).
//!
//! Run: `cargo run --release --example accuracy_eval`

use std::sync::Arc;

use pipesim::analytics::figures;
use pipesim::coordinator::{fit_params, ArrivalSpec, Experiment, ExperimentConfig};
use pipesim::des::DAY;
use pipesim::empirical::GroundTruth;
use pipesim::runtime::Runtime;
use pipesim::stats::pearson;

fn main() -> pipesim::Result<()> {
    let db = GroundTruth::new(19).generate_weeks(8);
    println!("{}", db.summary());
    let runtime = Runtime::load_default().map(Arc::new);
    let params = fit_params(&db, runtime.clone())?;

    let run = |arrival: ArrivalSpec, name: &str| {
        let cfg = ExperimentConfig {
            name: name.into(),
            seed: 23,
            horizon: 28.0 * DAY,
            arrival,
            ..Default::default()
        };
        Experiment::new(cfg, params.clone())
            .with_runtime(runtime.clone())
            .run()
    };

    println!("\n== Fig 12a: task-duration Q-Q (4 simulated weeks vs empirical) ==");
    let r_profile = run(ArrivalSpec::Profile, "accuracy-profile")?;
    for q in figures::fig12a_qq(&db, &r_profile, 60) {
        println!("{}", q.verdict());
    }

    println!("\n== Fig 12b: interarrival Q-Q ==");
    if let Some(q) = figures::fig12b_qq(&db, &r_profile, "realistic", 60) {
        println!("{}", q.verdict());
    }
    let r_random = run(ArrivalSpec::Random, "accuracy-random")?;
    if let Some(q) = figures::fig12b_qq(&db, &r_random, "random", 60) {
        println!("{}", q.verdict());
    }

    println!("\n== Fig 12c: arrivals per hour-of-week, simulated vs empirical ==");
    let csv = figures::fig12c_profile(&db, &r_profile);
    let mut emp = Vec::new();
    let mut sim = Vec::new();
    for line in csv.lines().skip(1) {
        let mut parts = line.split(',');
        parts.next();
        emp.push(parts.next().unwrap().parse::<f64>()?);
        sim.push(parts.next().unwrap().parse::<f64>()?);
    }
    let corr = pearson(&emp, &sim);
    println!("hour-of-week profile correlation (sim vs emp): {corr:.4}");
    let peak_emp = emp
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    let peak_sim = sim
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    println!(
        "empirical peak hour-of-week: {peak_emp} (day {}, {:02}:00); simulated: {peak_sim}",
        peak_emp / 24,
        peak_emp % 24
    );
    std::fs::write("fig12c_profile.csv", csv)?;
    println!("wrote fig12c_profile.csv");
    Ok(())
}
