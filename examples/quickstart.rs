//! Quickstart: the full PipeSim loop in one binary.
//!
//! 1. Generate a synthetic empirical analytics database (the stand-in for
//!    the paper's production usage DB).
//! 2. Fit every simulation model on it (asset GMM, per-framework duration
//!    mixtures, preprocess curve, arrival profile) — through the AOT PJRT
//!    artifacts when `artifacts/` is built, pure Rust otherwise.
//! 3. Run a 3-day experiment and render the dashboard.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use pipesim::analytics::render_dashboard;
use pipesim::coordinator::{fit_params, ArrivalSpec, Experiment, ExperimentConfig};
use pipesim::des::DAY;
use pipesim::empirical::GroundTruth;
use pipesim::runtime::Runtime;

fn main() -> pipesim::Result<()> {
    // 1. empirical substrate (8 weeks ≈ 32k training jobs)
    println!("== generating empirical database (8 weeks) ==");
    let db = GroundTruth::new(42).generate_weeks(8);
    println!("{}", db.summary());

    // 2. fit the modeled system
    let runtime = Runtime::load_default().map(Arc::new);
    println!(
        "== fitting simulation parameters ({}) ==",
        if runtime.is_some() { "PJRT artifacts" } else { "CPU fallback" }
    );
    let params = fit_params(&db, runtime.clone())?;
    println!(
        "preprocess curve: f(x) = {:.4}*{:.4}^x + {:.3}  (ground truth 0.018*1.330^x + 2.156)",
        params.preproc_curve.a, params.preproc_curve.b, params.preproc_curve.c
    );

    // 3. simulate 3 days under the realistic arrival profile
    println!("== simulating 3 days ==");
    let cfg = ExperimentConfig {
        name: "quickstart".into(),
        horizon: 3.0 * DAY,
        arrival: ArrivalSpec::Profile,
        ..Default::default()
    };
    let result = Experiment::new(cfg, params).with_runtime(runtime).run()?;
    println!("{}", render_dashboard(&result, 72));
    Ok(())
}
